//! `plam` — CLI for the PLAM reproduction.
//!
//! Subcommands:
//!   serve          start the batched inference server
//!   table2         reproduce Table II (accuracy across formats)
//!   hw-report      reproduce Table III / Fig. 1 / Fig. 5 / Fig. 6
//!   error          reproduce the §III.C error analysis
//!   selftest       quick end-to-end smoke of every subsystem
//!
//! (Hand-rolled argument parsing: clap is unavailable offline, and the
//! surface is 5 subcommands with a handful of flags.)

use std::sync::Arc;

use plam::coordinator::{serve, BatcherConfig, Frontend, NnBackend, Router, ServerConfig};
use plam::experiments;
use plam::nn::{ArithMode, Model};
use plam::posit::PositFormat;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    let code = match cmd {
        "serve" => cmd_serve(rest),
        "table2" => cmd_table2(rest),
        "hw-report" => cmd_hw_report(rest),
        "error" => cmd_error(),
        "selftest" => cmd_selftest(),
        "help" | "--help" | "-h" => {
            print_help();
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "plam — Posit Logarithm-Approximate Multiplier reproduction

USAGE: plam <command> [flags]

COMMANDS:
  serve      [--addr HOST:PORT] [--workers N] [--max-inflight N]
             [--frontend event-loop|threaded] [--loop-shards N]
             [--request-timeout-ms N] [--idle-timeout-ms N]
             [--admission-timeout-ms N] [--format-plan SPEC]
             [--fault-plan SPEC]
             [--artifact PATH --batch N --in N --out N]
             Start the batched inference server. Registers the Table I
             models in float32 / posit<16,1> / posit<16,1>+PLAM modes;
             optionally also a PJRT artifact backend (--features pjrt).
             --format-plan additionally registers each model under a
             per-layer mixed-format plan ('<name>-mixed' routes, PLAM
             multiplier). SPEC is 'uniform:p16e1',
             'first-last-wide:p16e1/p8e0', 'layers:p16e1,p8e0,...', or
             '@model.json' (per-layer 'format' fields, see README).
             --workers sizes the shared GEMM worker pool (default: the
             machine's parallelism; 0 disables it); --max-inflight is
             the admission-control bound (default 256, 0 = unlimited).
             --frontend picks the connection front-end: 'event-loop'
             (default; readiness-driven loops multiplex every
             connection) or 'threaded' (one thread per connection).
             --loop-shards sizes the event-loop front-end (default
             min(4, cores)): 1 = a single loop owning the listener,
             N>=2 = a dedicated acceptor fanning connections out to N
             independent loops (least-connections, round-robin ties).
             --request-timeout-ms bounds a request's batch-queue wait
             (0 = none, default 0; event-loop only); --idle-timeout-ms
             sheds silent idle connections (default 30000);
             --admission-timeout-ms bounds the wait for an inflight
             slot before shedding (default 10000).
             --fault-plan enables seeded deterministic fault injection
             for chaos testing (also read from the PLAM_FAULT_PLAN env
             var; the flag wins). SPEC is ';'-separated 'site=schedule'
             pairs plus an optional 'seed=N', e.g.
             'seed=42;worker_panic=every:7;backend_error=rate:0.05';
             schedules are 'every:N' or 'rate:F'. Sites: worker_panic,
             backend_error, callback_drop, short_write, spurious_wake,
             conn_reset, cache_evict. See README 'Failure model'.
  table2     [--quick | --full] [--plans]
             Reproduce Table II (inference accuracy across formats).
             --plans adds the mixed-format grid: accuracy + encoded
             bytes per format plan (uniform-P16E1 / first-last-wide /
             uniform-P8E0) for every dataset.
  hw-report  [--table3] [--fig1] [--fig5] [--fig6] [--headline]
             Reproduce the hardware evaluation (all when no flag given).
  error      Reproduce the §III.C approximation-error analysis.
  selftest   Smoke-test every subsystem.
"
    );
}

/// Parse `--flag value` pairs out of an argument list.
fn flag_value<'a>(args: &'a [String], flag: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
}

fn has_flag(args: &[String], flag: &str) -> bool {
    args.iter().any(|a| a == flag)
}

fn cmd_serve(args: &[String]) -> i32 {
    let addr = flag_value(args, "--addr").unwrap_or("127.0.0.1:7070");
    let mut router = Router::new();
    let cfg = BatcherConfig::default();

    // Fault injection (chaos testing): --fault-plan wins over the
    // PLAM_FAULT_PLAN env var; neither means injection stays a no-op.
    let installed = match flag_value(args, "--fault-plan") {
        Some(spec) => match plam::faults::FaultPlan::parse(spec) {
            Ok(plan) => Ok(plam::faults::install(plan)),
            Err(e) => {
                eprintln!("bad --fault-plan: {e:#}");
                return 2;
            }
        },
        None => plam::faults::install_from_env(),
    };
    match installed {
        Ok(true) => {
            let sites: Vec<&str> = plam::faults::installed()
                .map(|p| p.sites().iter().map(|s| s.name()).collect())
                .unwrap_or_default();
            println!("FAULT INJECTION ACTIVE (chaos mode): sites [{}]", sites.join(", "));
        }
        Ok(false) => {}
        Err(e) => {
            eprintln!("bad {}: {e:#}", plam::faults::ENV_VAR);
            return 2;
        }
    }

    // Optional per-layer format plan: every registered NN model gains a
    // '<name>-mixed' route running the plan (PLAM multiplier).
    let plan = match flag_value(args, "--format-plan") {
        Some(spec) => {
            let parsed = match spec.strip_prefix('@') {
                Some(path) => plam::nn::loader::load_format_plan(std::path::Path::new(path)),
                None => plam::nn::FormatPlan::parse(spec),
            };
            match parsed {
                Ok(p) => {
                    println!("format plan: {p}");
                    Some(p)
                }
                Err(e) => {
                    eprintln!("bad --format-plan: {e:#}");
                    return 2;
                }
            }
        }
        None => None,
    };

    // Register the ISOLET MLP in all three arithmetic modes (weights are
    // whatever artifacts provide; fall back to random init for a demo
    // service — accuracy experiments use `table2`).
    let mut rng = plam::prng::Rng::new(1);
    let kinds = [
        (plam::data::DatasetKind::Isolet, "isolet"),
        (plam::data::DatasetKind::UciHar, "har"),
    ];
    for (kind, name) in kinds {
        let mkind = experiments::model_for(kind);
        let mut model = Model::init(mkind, &mut rng);
        let wpath = std::path::Path::new("artifacts/weights").join(format!("{name}.ptw"));
        if wpath.exists() {
            if let Ok(w) = plam::nn::loader::load_weights(&wpath) {
                let _ = plam::nn::loader::apply_weights(&mut model, &w);
            }
        }
        router.register(
            &format!("{name}-f32"),
            Arc::new(NnBackend::new(model.clone(), ArithMode::float32())),
            cfg,
        );
        router.register(
            &format!("{name}-posit"),
            Arc::new(NnBackend::new(
                model.clone(),
                ArithMode::posit_exact(PositFormat::P16E1),
            )),
            cfg,
        );
        if let Some(plan) = &plan {
            // Base the mode on the plan's representative format; each
            // layer still resolves to its own format.
            let base = plan.representative_format().unwrap_or(PositFormat::P16E1);
            match NnBackend::with_plan(model.clone(), ArithMode::posit_plam(base), plan) {
                Ok(be) => router.register(&format!("{name}-mixed"), Arc::new(be), cfg),
                Err(e) => {
                    eprintln!("--format-plan does not fit model '{name}': {e:#}");
                    return 2;
                }
            }
        }
        router.register(
            &format!("{name}-plam"),
            Arc::new(NnBackend::new(
                model,
                ArithMode::posit_plam(PositFormat::P16E1),
            )),
            cfg,
        );
    }

    // Optional PJRT artifact route (the L1/L2 compiled path).
    #[cfg(feature = "pjrt")]
    {
        if let Some(artifact) = flag_value(args, "--artifact") {
            let batch: usize = flag_value(args, "--batch").unwrap_or("8").parse().unwrap_or(8);
            let in_len: usize = flag_value(args, "--in").unwrap_or("64").parse().unwrap_or(64);
            let out_len: usize = flag_value(args, "--out").unwrap_or("64").parse().unwrap_or(64);
            let loaded = plam::coordinator::PjrtBackend::load(
                std::path::Path::new(artifact),
                batch,
                in_len,
                out_len,
            );
            match loaded {
                Ok(be) => {
                    println!("loaded PJRT artifact {artifact} on {}", be.platform());
                    router.register("pjrt", Arc::new(be), cfg);
                }
                Err(e) => {
                    eprintln!("failed to load artifact {artifact}: {e:#}");
                    return 1;
                }
            }
        }
    }
    #[cfg(not(feature = "pjrt"))]
    {
        // Fail fast, matching the pjrt build's behavior when an
        // artifact cannot be loaded: a server silently missing the
        // requested route helps nobody.
        if flag_value(args, "--artifact").is_some() {
            eprintln!("--artifact requires a build with `--features pjrt`");
            return 1;
        }
    }

    // GEMM worker pool: default to the machine's parallelism; override
    // with --workers N (0 = single-threaded batches). --max-inflight
    // bounds concurrently admitted requests (0 = unlimited).
    let default_workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let workers: usize = flag_value(args, "--workers")
        .and_then(|v| v.parse().ok())
        .unwrap_or(default_workers);
    let max_inflight: usize = flag_value(args, "--max-inflight")
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let frontend = match flag_value(args, "--frontend").unwrap_or("event-loop") {
        "event-loop" => Frontend::EventLoop,
        "threaded" => Frontend::Threaded,
        other => {
            eprintln!("bad --frontend '{other}' (expected 'event-loop' or 'threaded')");
            return 2;
        }
    };
    // Event-loop shard count: min(4, cores) spreads front-end CPU
    // without oversubscribing small machines; 1 is the single-loop
    // front-end.
    let default_shards = default_workers.clamp(1, 4);
    let loop_shards: usize = match flag_value(args, "--loop-shards") {
        None => default_shards,
        Some(v) => match v.parse() {
            Ok(n) if n >= 1 => n,
            _ => {
                eprintln!("bad --loop-shards '{v}' (expected an integer >= 1)");
                return 2;
            }
        },
    };
    let ms_flag = |flag: &str, default: u64| -> u64 {
        flag_value(args, flag)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    };
    // 0 means "no per-request deadline".
    let request_timeout = match ms_flag("--request-timeout-ms", 0) {
        0 => None,
        ms => Some(std::time::Duration::from_millis(ms)),
    };
    let idle_timeout = std::time::Duration::from_millis(ms_flag("--idle-timeout-ms", 30_000));
    let admission_timeout =
        std::time::Duration::from_millis(ms_flag("--admission-timeout-ms", 10_000));

    println!("routing table:\n{}", router.table());
    match serve(
        router,
        &ServerConfig {
            addr: addr.into(),
            workers,
            max_inflight,
            admission_timeout,
            frontend,
            loop_shards,
            request_timeout,
            idle_timeout,
        },
    ) {
        Ok(h) => {
            println!(
                "plam server listening on {} (frontend={frontend:?}, loop_shards={loop_shards}, \
                 workers={workers}, max_inflight={max_inflight})",
                h.addr
            );
            loop {
                std::thread::sleep(std::time::Duration::from_secs(60));
                for name in h.router().model_names() {
                    if let Ok(b) = h.router().get(&name) {
                        println!("{name}: {}", b.metrics.summary());
                    }
                }
            }
        }
        Err(e) => {
            eprintln!("serve failed: {e:#}");
            1
        }
    }
}

fn cmd_table2(args: &[String]) -> i32 {
    let cfg = if has_flag(args, "--full") {
        experiments::Table2Config::full()
    } else {
        experiments::Table2Config::quick()
    };
    let rows = experiments::table2(&cfg);
    println!("{}", experiments::render_table2(&rows));
    if has_flag(args, "--plans") {
        // The mixed-format grid: every Table II dataset × the default
        // plan trio (uniform-P16E1 / first-last-wide / uniform-P8E0).
        let plans = experiments::default_plan_grid();
        let mut rows = Vec::new();
        for &kind in &cfg.datasets {
            rows.extend(experiments::table2_plan_sweep(kind, &cfg, &plans));
        }
        println!("{}", experiments::render_plan_sweep(&rows));
    }
    0
}

fn cmd_hw_report(args: &[String]) -> i32 {
    let all = args.is_empty();
    if all || has_flag(args, "--table3") {
        println!("{}", plam::hardware::render_table3());
    }
    if all || has_flag(args, "--fig1") {
        println!("{}", plam::hardware::render_fig1());
    }
    if all || has_flag(args, "--fig5") {
        println!("{}", plam::hardware::render_fig5());
    }
    if all || has_flag(args, "--fig6") {
        println!("{}", plam::hardware::render_fig6());
    }
    if all || has_flag(args, "--headline") {
        println!("{}", plam::hardware::render_headline());
    }
    0
}

fn cmd_error() -> i32 {
    println!("{}", experiments::render_error_analysis());
    0
}

fn cmd_selftest() -> i32 {
    use plam::posit::P16E1;
    println!("posit arithmetic:");
    let a = P16E1::from_f64(1.5);
    let b = P16E1::from_f64(2.25);
    println!("  1.5 × 2.25        = {} (exact)", a * b);
    println!("  1.5 ×̃ 2.25        = {} (PLAM)", a.plam_mul(b));

    println!("hardware model headline:");
    let h = plam::hardware::headline();
    println!(
        "  area -{:.1}%  power -{:.1}%  delay -{:.1}% (32-bit vs exact posit)",
        h.area_reduction_32 * 100.0,
        h.power_reduction_32 * 100.0,
        h.delay_reduction_32 * 100.0
    );

    println!("inference server:");
    let mut router = Router::new();
    router.register(
        "demo",
        Arc::new(NnBackend::new(
            Model::new(plam::nn::ModelKind::MlpIsolet),
            ArithMode::posit_plam(PositFormat::P16E1),
        )),
        BatcherConfig::default(),
    );
    match serve(
        router,
        &ServerConfig {
            workers: 2,
            ..ServerConfig::default()
        },
    ) {
        Ok(h) => {
            let mut c = plam::coordinator::Client::connect(h.addr).unwrap();
            let out = c.infer("demo", &vec![0.1; 617]).unwrap();
            println!("  demo inference over TCP: {} logits ✓", out.len());
            h.shutdown();
        }
        Err(e) => {
            eprintln!("  server failed: {e:#}");
            return 1;
        }
    }

    #[cfg(feature = "pjrt")]
    {
        println!("PJRT runtime:");
        match plam::runtime::Runtime::cpu() {
            Ok(rt) => println!("  platform: {} ✓", rt.platform()),
            Err(e) => {
                eprintln!("  unavailable: {e:#}");
                return 1;
            }
        }
        // (Runtime::cpu() is !Send; the serving path uses
        // ThreadedExecutable.)
    }
    #[cfg(not(feature = "pjrt"))]
    {
        println!("PJRT runtime: skipped (build with `--features pjrt`)");
    }
    println!("selftest OK");
    0
}
