//! Synthetic dataset generators (DESIGN.md §5 substitution for MNIST /
//! SVHN / CIFAR-10 / ISOLET / UCI HAR).

pub mod synth;

pub use synth::{Dataset, DatasetKind};
