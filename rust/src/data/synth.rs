//! Deterministic synthetic datasets matching the paper's Table I shapes.
//!
//! The real MNIST / SVHN / CIFAR-10 / ISOLET / UCI-HAR downloads are not
//! available offline, so each is replaced by a generator with the same
//! tensor shapes, class counts and a comparable decision structure
//! (DESIGN.md §5): Table II's claim — PLAM inference ≈ exact-posit ≈
//! float32 — is about multiplier error vs decision margins, which these
//! tasks exercise identically.
//!
//! * Numeric sets (ISOLET 617-D/26-way, HAR 561-D/6-way): anisotropic
//!   Gaussian clusters around random class prototypes with nuisance
//!   dimensions and inter-class correlation.
//! * Image sets (MNIST 1×28×28, SVHN 3×32×32, CIFAR 3×32×32): 10 classes
//!   of procedurally rendered oriented shapes (strokes/blobs/gratings)
//!   with jitter, scale/rotation noise, background clutter and, for the
//!   colour sets, hue variation.

use crate::nn::tensor::Tensor;
use crate::prng::Rng;

/// Which paper dataset a generator stands in for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// 617 features, 26 classes (spoken letters).
    Isolet,
    /// 561 features, 6 classes (activity recognition).
    UciHar,
    /// 1×28×28 images, 10 classes.
    Mnist,
    /// 3×32×32 images, 10 classes.
    Svhn,
    /// 3×32×32 images, 10 classes.
    Cifar10,
}

impl DatasetKind {
    /// Input tensor shape of one sample.
    pub fn input_shape(&self) -> Vec<usize> {
        match self {
            DatasetKind::Isolet => vec![617],
            DatasetKind::UciHar => vec![561],
            DatasetKind::Mnist => vec![1, 28, 28],
            DatasetKind::Svhn | DatasetKind::Cifar10 => vec![3, 32, 32],
        }
    }

    /// Number of classes.
    pub fn classes(&self) -> usize {
        match self {
            DatasetKind::Isolet => 26,
            DatasetKind::UciHar => 6,
            _ => 10,
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::Isolet => "isolet(synth)",
            DatasetKind::UciHar => "uci-har(synth)",
            DatasetKind::Mnist => "mnist(synth)",
            DatasetKind::Svhn => "svhn(synth)",
            DatasetKind::Cifar10 => "cifar10(synth)",
        }
    }

    /// Task difficulty knob: noise level relative to class separation.
    fn noise(&self) -> f64 {
        match self {
            DatasetKind::Isolet => 1.7,
            DatasetKind::UciHar => 3.2,
            DatasetKind::Mnist => 0.35,
            DatasetKind::Svhn => 1.35,
            DatasetKind::Cifar10 => 1.45,
        }
    }
}

/// A labelled split.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub kind: DatasetKind,
    pub train_x: Vec<Tensor>,
    pub train_y: Vec<usize>,
    pub test_x: Vec<Tensor>,
    pub test_y: Vec<usize>,
}

impl Dataset {
    /// Generate a dataset deterministically from a seed.
    pub fn generate(kind: DatasetKind, train_n: usize, test_n: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xDA7A_5E7);
        match kind {
            DatasetKind::Isolet | DatasetKind::UciHar => {
                Self::generate_numeric(kind, train_n, test_n, &mut rng)
            }
            _ => Self::generate_images(kind, train_n, test_n, &mut rng),
        }
    }

    fn generate_numeric(kind: DatasetKind, train_n: usize, test_n: usize, rng: &mut Rng) -> Self {
        let dim = kind.input_shape()[0];
        let classes = kind.classes();
        let noise = kind.noise();
        // Class prototypes: sparse informative dims + shared correlation
        // basis, mimicking featurised audio/IMU data.
        let informative = dim / 3;
        let mut protos = vec![vec![0f32; dim]; classes];
        for p in protos.iter_mut() {
            for j in 0..informative {
                p[j] = rng.normal() as f32;
            }
        }
        // Random rotation mixing informative dims into all dims (rank-
        // deficient linear map keeps it cheap: y = P + B·z).
        let mixers: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..dim).map(|_| (rng.f32() - 0.5) * 0.6).collect())
            .collect();

        let gen_split = |n: usize, rng: &mut Rng| {
            let mut xs = Vec::with_capacity(n);
            let mut ys = Vec::with_capacity(n);
            for i in 0..n {
                let class = i % classes;
                let mut v = protos[class].clone();
                // Correlated nuisance.
                for m in &mixers {
                    let z = rng.normal() as f32;
                    for (vj, mj) in v.iter_mut().zip(m.iter()) {
                        *vj += z * mj;
                    }
                }
                // Per-dim noise.
                for vj in v.iter_mut() {
                    *vj += (noise * rng.normal()) as f32;
                }
                xs.push(Tensor::from_vec(&[dim], v));
                ys.push(class);
            }
            (xs, ys)
        };
        let (train_x, train_y) = gen_split(train_n, rng);
        let (test_x, test_y) = gen_split(test_n, rng);
        Dataset {
            kind,
            train_x,
            train_y,
            test_x,
            test_y,
        }
    }

    fn generate_images(kind: DatasetKind, train_n: usize, test_n: usize, rng: &mut Rng) -> Self {
        let shape = kind.input_shape();
        let (ch, hw) = (shape[0], shape[1]);
        let noise = kind.noise();

        let gen_split = |n: usize, rng: &mut Rng| {
            let mut xs = Vec::with_capacity(n);
            let mut ys = Vec::with_capacity(n);
            for i in 0..n {
                let class = i % 10;
                xs.push(render_shape(class, ch, hw, noise, rng));
                ys.push(class);
            }
            (xs, ys)
        };
        let (train_x, train_y) = gen_split(train_n, rng);
        let (test_x, test_y) = gen_split(test_n, rng);
        Dataset {
            kind,
            train_x,
            train_y,
            test_x,
            test_y,
        }
    }
}

/// Render one image of the given class: each class is a distinct
/// parametric pattern (orientation × shape family), jittered per sample.
fn render_shape(class: usize, ch: usize, hw: usize, noise: f64, rng: &mut Rng) -> Tensor {
    let mut img = Tensor::zeros(&[ch, hw, hw]);
    let cx = hw as f64 / 2.0 + rng.normal() * 1.5;
    let cy = hw as f64 / 2.0 + rng.normal() * 1.5;
    let scale = hw as f64 * (0.28 + 0.06 * rng.normal().clamp(-1.5, 1.5));
    // Class → pattern parameters: 5 orientations × 2 families.
    let angle = (class % 5) as f64 * core::f64::consts::PI / 5.0 + rng.normal() * 0.08;
    let family = class / 5; // 0: bar/cross strokes, 1: rings/gratings
    let (sa, ca) = angle.sin_cos();
    // Per-sample hue for colour sets.
    let hue: Vec<f64> = (0..ch)
        .map(|c| 0.65 + 0.35 * ((class as f64 * 0.7 + c as f64 * 2.1).sin()) + rng.normal() * 0.05)
        .collect();

    for y in 0..hw {
        for x in 0..hw {
            let dx = x as f64 - cx;
            let dy = y as f64 - cy;
            // Rotate into the class frame.
            let u = ca * dx + sa * dy;
            let v = -sa * dx + ca * dy;
            let r = (dx * dx + dy * dy).sqrt();
            let intensity = match family {
                0 => {
                    // Oriented bar + perpendicular tick (digit-stroke-ish).
                    let bar = (-((v / (scale * 0.18)).powi(2))).exp();
                    let tick = (-((u / (scale * 0.15)).powi(2)) - ((v - scale * 0.4) / (scale * 0.3)).powi(2)).exp();
                    (bar + 0.7 * tick).min(1.0)
                }
                _ => {
                    // Ring + oriented grating.
                    let ring = (-(((r - scale * 0.8) / (scale * 0.2)).powi(2))).exp();
                    let grating = 0.5 + 0.5 * (u / scale * 6.0).sin();
                    (0.8 * ring + 0.4 * grating * (-(r / scale / 1.4).powi(2)).exp()).min(1.0)
                }
            };
            for c in 0..ch {
                let clutter = noise * 0.5 * rng.normal();
                let val = intensity * hue[c] + clutter;
                *img.at3_mut(c, y, x) = val.clamp(0.0, 1.0) as f32;
            }
        }
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_classes_match_table1() {
        for kind in [
            DatasetKind::Isolet,
            DatasetKind::UciHar,
            DatasetKind::Mnist,
            DatasetKind::Svhn,
            DatasetKind::Cifar10,
        ] {
            let d = Dataset::generate(kind, 20, 10, 7);
            assert_eq!(d.train_x.len(), 20);
            assert_eq!(d.test_x.len(), 10);
            assert_eq!(d.train_x[0].shape, kind.input_shape());
            assert!(d.train_y.iter().all(|&y| y < kind.classes()));
            // All classes present in a large-enough split.
            let mut seen = vec![false; kind.classes()];
            let d2 = Dataset::generate(kind, 4 * kind.classes(), 0, 7);
            for &y in &d2.train_y {
                seen[y] = true;
            }
            assert!(seen.iter().all(|&s| s), "{kind:?} missing classes");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Dataset::generate(DatasetKind::Mnist, 5, 5, 42);
        let b = Dataset::generate(DatasetKind::Mnist, 5, 5, 42);
        assert_eq!(a.train_x[0].data, b.train_x[0].data);
        let c = Dataset::generate(DatasetKind::Mnist, 5, 5, 43);
        assert_ne!(a.train_x[0].data, c.train_x[0].data);
    }

    #[test]
    fn images_are_normalised() {
        let d = Dataset::generate(DatasetKind::Cifar10, 10, 0, 1);
        for x in &d.train_x {
            for &v in &x.data {
                assert!((0.0..=1.0).contains(&v));
            }
        }
    }

    #[test]
    fn classes_are_visually_distinct() {
        // Mean intra-class L2 distance < mean inter-class distance.
        let d = Dataset::generate(DatasetKind::Mnist, 60, 0, 3);
        let dist = |a: &Tensor, b: &Tensor| -> f64 {
            a.data
                .iter()
                .zip(b.data.iter())
                .map(|(x, y)| ((x - y) as f64).powi(2))
                .sum::<f64>()
        };
        let (mut intra, mut ni) = (0.0, 0);
        let (mut inter, mut nx) = (0.0, 0);
        for i in 0..d.train_x.len() {
            for j in (i + 1)..d.train_x.len() {
                let dd = dist(&d.train_x[i], &d.train_x[j]);
                if d.train_y[i] == d.train_y[j] {
                    intra += dd;
                    ni += 1;
                } else {
                    inter += dd;
                    nx += 1;
                }
            }
        }
        assert!(intra / (ni as f64) < inter / nx as f64);
    }
}
