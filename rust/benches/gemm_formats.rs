//! Bench: GEMM throughput across arithmetic formats — the software-
//! emulation ablation behind Table II's cost story (float32 vs exact
//! posit vs PLAM, quire vs f32 accumulation), the scalar-dot vs
//! batched-GEMM comparison across P8E0/P16E1/P32E2, the windowed
//! single-limb vs FastQuire accumulator ablation (exact + PLAM, plus a
//! skinny M=1 GEMV), the narrow/SIMD vs wide-forced P8E0 plane
//! ablation, plus the AOT PJRT kernel when artifacts are present. The
//! exported `BENCH_gemm_formats.json` feeds
//! `ci/check_bench_regression.py` — keep series names stable.
//!
//! Run: cargo bench --bench gemm_formats   (PLAM_BENCH_FAST=1 for smoke)

use plam::bench::{black_box, Bench};
use plam::nn::gemm::{
    encode_matrix, encode_matrix_wide, gemm_bt, gemm_bt_pool, gemm_bt_with_policy, AccPolicy,
};
use plam::nn::{ArithMode, Layer, Tensor, WorkerPool};
use plam::posit::PositFormat;
use plam::prng::Rng;

fn random_tensor(shape: &[usize], rng: &mut Rng) -> Tensor {
    Tensor::from_vec(
        shape,
        (0..shape.iter().product::<usize>())
            .map(|_| rng.normal() as f32 * 0.5)
            .collect(),
    )
}

fn main() {
    let mut bench = Bench::new();
    let mut rng = Rng::new(5);

    // Dense layer (out=128, in=256): one ISOLET-scale matvec per call.
    let layer = Layer::Dense {
        w: random_tensor(&[128, 256], &mut rng),
        b: random_tensor(&[128], &mut rng),
    };
    let x = random_tensor(&[256], &mut rng);
    let macs = 128 * 256;

    let modes = [
        ("float32", ArithMode::float32()),
        ("posit16-exact", ArithMode::posit_exact(PositFormat::P16E1)),
        ("posit16-plam", ArithMode::posit_plam(PositFormat::P16E1)),
    ];
    println!("dense 256→128 ({macs} MACs):");
    let mut results = vec![];
    for (name, mode) in &modes {
        let r = bench
            .run(&format!("dense {name}"), || {
                black_box(layer.forward(&x, mode));
            })
            .clone();
        results.push((name.to_string(), r));
    }
    println!("\nMAC throughput:");
    for (name, r) in &results {
        println!("  {:<16} {:>12.0} MAC/s", name, r.ops_per_sec(macs as f64));
    }
    let slowdown = |a: usize, b: usize| {
        results[a].1.mean.as_secs_f64() / results[b].1.mean.as_secs_f64()
    };
    println!(
        "  PLAM vs exact posit: {:.2}× faster (software analogue of the mult removal)",
        slowdown(1, 2)
    );

    // Prepared-model ablation: weights pre-encoded once (perf pass) —
    // measured on a single-Dense model so the series is comparable.
    use plam::nn::{Model, PreparedModel};
    let dense_model = Model {
        name: "bench-dense".into(),
        layers: vec![layer.clone()],
        input_shape: vec![256],
    };
    for (name, mode) in &modes {
        let prepared = PreparedModel::new(&dense_model, mode.clone());
        let r = bench
            .run(&format!("dense {name} (prepared)"), || {
                black_box(prepared.forward(&x));
            })
            .clone();
        println!(
            "  {:<16} prepared: {:>12.0} MAC/s",
            name,
            r.ops_per_sec(macs as f64)
        );
    }

    // Conv layer (LeNet C1 shape).
    let conv = Layer::Conv2d {
        w: random_tensor(&[6, 1, 5, 5], &mut rng),
        b: random_tensor(&[6], &mut rng),
        stride: 1,
        pad: 2,
    };
    let img = random_tensor(&[1, 28, 28], &mut rng);
    for (name, mode) in &modes {
        bench.run(&format!("conv lenet-c1 {name}"), || {
            black_box(conv.forward(&img, mode));
        });
    }

    // -----------------------------------------------------------------
    // Scalar-dot vs batched GEMM, per format: the decode-once payoff.
    //
    // The scalar path is the per-sample layer engine (re-encodes the
    // weight matrix for every sample, one dot product per output); the
    // GEMM path pre-encodes the weight plane once (as PreparedModel /
    // the serving batcher do) and runs the whole batch as one
    // cache-blocked [batch, k] × [n, k]ᵀ GEMM.
    // -----------------------------------------------------------------
    println!("\nscalar-dot vs batched GEMM (dense 256→256, batch 8, PLAM):");
    let formats = [
        ("p8e0", PositFormat::P8E0),
        ("p16e1", PositFormat::P16E1),
        ("p32e2", PositFormat::P32E2),
    ];
    let (k_dim, n_dim, batch) = (256usize, 256usize, 8usize);
    let wt = random_tensor(&[n_dim, k_dim], &mut rng);
    let bt = random_tensor(&[n_dim], &mut rng);
    let xs: Vec<Tensor> = (0..batch)
        .map(|_| random_tensor(&[k_dim], &mut rng))
        .collect();
    let flat: Vec<f32> = xs.iter().flat_map(|t| t.data.iter().copied()).collect();
    let batch_macs = (batch * k_dim * n_dim) as f64;
    for (fname, fmt) in formats {
        let mode = ArithMode::posit_plam(fmt);
        let layer = Layer::Dense {
            w: wt.clone(),
            b: bt.clone(),
        };
        let scalar = bench
            .run(&format!("scalar-dot plam {fname} 256x256 batch{batch}"), || {
                for x in &xs {
                    black_box(layer.forward(x, &mode));
                }
            })
            .clone();
        let we = encode_matrix(&mode, n_dim, k_dim, &wt.data); // decode once
        let mut y = vec![0f32; batch * n_dim];
        let gemm = bench
            .run(&format!("gemm plam {fname} 256x256 batch{batch}"), || {
                let xe = encode_matrix(&mode, batch, k_dim, &flat);
                gemm_bt(&mode, &xe, &we, Some(&bt.data), &mut y);
                black_box(&y);
            })
            .clone();
        println!(
            "  {fname:<7} scalar {:>12.0} MAC/s   gemm {:>12.0} MAC/s   speedup {:.2}×",
            scalar.ops_per_sec(batch_macs),
            gemm.ops_per_sec(batch_macs),
            scalar.mean.as_secs_f64() / gemm.mean.as_secs_f64()
        );
    }

    // Acceptance series: full 256×256×256 P16E1 PLAM matmul (batch =
    // 256 samples through a 256→256 dense layer), scalar vs GEMM.
    println!("\n256×256×256 P16E1 PLAM matmul (batch 256):");
    {
        let mode = ArithMode::posit_plam(PositFormat::P16E1);
        let m_dim = 256usize;
        let xs256: Vec<Tensor> = (0..m_dim)
            .map(|_| random_tensor(&[k_dim], &mut rng))
            .collect();
        let flat256: Vec<f32> = xs256.iter().flat_map(|t| t.data.iter().copied()).collect();
        let layer = Layer::Dense {
            w: wt.clone(),
            b: bt.clone(),
        };
        let macs = (m_dim * k_dim * n_dim) as f64;
        let scalar = bench
            .run("scalar-dot plam p16e1 256^3", || {
                for x in &xs256 {
                    black_box(layer.forward(x, &mode));
                }
            })
            .clone();
        let we = encode_matrix(&mode, n_dim, k_dim, &wt.data);
        let mut y = vec![0f32; m_dim * n_dim];
        let gemm = bench
            .run("gemm plam p16e1 256^3", || {
                let xe = encode_matrix(&mode, m_dim, k_dim, &flat256);
                gemm_bt(&mode, &xe, &we, Some(&bt.data), &mut y);
                black_box(&y);
            })
            .clone();
        let speedup = scalar.mean.as_secs_f64() / gemm.mean.as_secs_f64();
        println!(
            "  scalar {:>12.0} MAC/s   gemm {:>12.0} MAC/s   speedup {speedup:.2}× (target ≥ 2×)",
            scalar.ops_per_sec(macs),
            gemm.ops_per_sec(macs),
        );
    }

    // -----------------------------------------------------------------
    // Worker-pool scaling series: the same 256×256×256 P16E1 PLAM GEMM
    // sharded across 1/2/4/8 pool workers. Operands are pre-encoded so
    // the series isolates MAC scaling; workers=1 routes through the
    // sequential kernel (a 1-worker pool degrades to inline execution),
    // making it the honest single-thread baseline. Acceptance: ≥ 2.5×
    // at 4 workers on a 4-core runner.
    // -----------------------------------------------------------------
    println!("\nworker-pool scaling (256×256×256 P16E1 PLAM GEMM):");
    {
        let mode = ArithMode::posit_plam(PositFormat::P16E1);
        let m_dim = 256usize;
        let xs: Vec<Tensor> = (0..m_dim)
            .map(|_| random_tensor(&[k_dim], &mut rng))
            .collect();
        let flat: Vec<f32> = xs.iter().flat_map(|t| t.data.iter().copied()).collect();
        let xe = encode_matrix(&mode, m_dim, k_dim, &flat);
        let we = encode_matrix(&mode, n_dim, k_dim, &wt.data);
        let mut y = vec![0f32; m_dim * n_dim];
        let macs = (m_dim * k_dim * n_dim) as f64;
        let series_name = |w: usize| format!("gemm plam p16e1 256^3 workers={w}");
        for workers in [1usize, 2, 4, 8] {
            let pool = WorkerPool::new(workers);
            let r = bench
                .run(&series_name(workers), || {
                    gemm_bt_pool(&mode, &xe, &we, Some(&bt.data), &mut y, &pool);
                    black_box(&y);
                })
                .clone();
            let speedup = bench
                .speedup(&series_name(1), &series_name(workers))
                .unwrap_or(1.0);
            println!(
                "  workers={workers}  {:>12.0} MAC/s   speedup vs 1 worker {speedup:.2}×",
                r.ops_per_sec(macs),
            );
            pool.shutdown();
        }
        if let Some(s4) = bench.speedup(&series_name(1), &series_name(4)) {
            println!("  4-worker speedup {s4:.2}× (target ≥ 2.5×)");
        }
    }

    // -----------------------------------------------------------------
    // Windowed vs FastQuire accumulation: AccPolicy::Auto picks the
    // scale-windowed single-limb i128 kernel whenever an output row
    // pair's scale window fits (always, for these Gaussian operands);
    // ForceQuire is the pre-windowing baseline. Operands are
    // pre-encoded so each series isolates pure MAC throughput.
    // Acceptance: ≥ 1.5× on the 256³ P16E1 PLAM case.
    // -----------------------------------------------------------------
    println!("\nwindowed vs FastQuire accumulation (256×256×256, exact + PLAM):");
    {
        let m_dim = 256usize;
        let flat: Vec<f32> = (0..m_dim * k_dim)
            .map(|_| rng.normal() as f32 * 0.5)
            .collect();
        let macs = (m_dim * k_dim * n_dim) as f64;
        let muls: [(&str, fn(PositFormat) -> ArithMode); 2] = [
            ("exact", ArithMode::posit_exact),
            ("plam", ArithMode::posit_plam),
        ];
        for (fname, fmt) in formats {
            for (mname, mk) in muls {
                let mode = mk(fmt);
                let xe = encode_matrix(&mode, m_dim, k_dim, &flat);
                let we = encode_matrix(&mode, n_dim, k_dim, &wt.data);
                let mut y = vec![0f32; m_dim * n_dim];
                let win_name = format!("gemm {mname} {fname} 256^3 windowed");
                let fq_name = format!("gemm {mname} {fname} 256^3 fastquire");
                let win = bench
                    .run(&win_name, || {
                        gemm_bt_with_policy(
                            &mode,
                            &xe,
                            &we,
                            Some(&bt.data),
                            &mut y,
                            AccPolicy::Auto,
                        );
                        black_box(&y);
                    })
                    .clone();
                let fq = bench
                    .run(&fq_name, || {
                        gemm_bt_with_policy(
                            &mode,
                            &xe,
                            &we,
                            Some(&bt.data),
                            &mut y,
                            AccPolicy::ForceQuire,
                        );
                        black_box(&y);
                    })
                    .clone();
                let speedup = bench.speedup(&fq_name, &win_name).unwrap_or(1.0);
                println!(
                    "  {mname:<5} {fname:<6} windowed {:>12.0} MAC/s   fastquire {:>12.0} \
                     MAC/s   speedup {speedup:.2}×{}",
                    win.ops_per_sec(macs),
                    fq.ops_per_sec(macs),
                    if mname == "plam" && fname == "p16e1" {
                        "  (target ≥ 1.5×)"
                    } else {
                        ""
                    },
                );
            }
        }

        // Sub-wide-vs-wide ablation: the same 256³ operands forced into
        // the wide (6 B/element) scalar layout — the reference the
        // SIMD sub-wide series above are measured against (n ≤ 8
        // encodes pick 2 B/element narrow planes, 16-bit formats pick
        // 3 B/element mid planes; both are vector-eligible under
        // AccPolicy::Auto).
        for (fname, fmt, target) in [
            ("p8e0", PositFormat::P8E0, "1.5"),
            ("p16e1", PositFormat::P16E1, "1.3"),
        ] {
            for (mname, mk) in muls {
                let mode = mk(fmt);
                let xe = encode_matrix_wide(&mode, m_dim, k_dim, &flat);
                let we = encode_matrix_wide(&mode, n_dim, k_dim, &wt.data);
                let mut y = vec![0f32; m_dim * n_dim];
                let wide_name = format!("gemm {mname} {fname} 256^3 windowed wide");
                let r = bench
                    .run(&wide_name, || {
                        gemm_bt_with_policy(
                            &mode,
                            &xe,
                            &we,
                            Some(&bt.data),
                            &mut y,
                            AccPolicy::Auto,
                        );
                        black_box(&y);
                    })
                    .clone();
                let subwide_name = format!("gemm {mname} {fname} 256^3 windowed");
                let speedup = bench.speedup(&wide_name, &subwide_name).unwrap_or(1.0);
                println!(
                    "  {mname:<5} {fname:<6} wide planes {:>12.0} MAC/s   sub-wide/SIMD speedup \
                     {speedup:.2}× (soft target ≥ {target}×)",
                    r.ops_per_sec(macs),
                );
            }
        }

        // Skinny GEMV (M=1): the per-request serving shape — the
        // planner and scratch must not pay tile-sized overheads for a
        // single output row.
        let mode = ArithMode::posit_plam(PositFormat::P16E1);
        let xe = encode_matrix(&mode, 1, k_dim, &flat[..k_dim]);
        let we = encode_matrix(&mode, n_dim, k_dim, &wt.data);
        let mut y = vec![0f32; n_dim];
        let gemv_macs = (k_dim * n_dim) as f64;
        let wname = "gemv plam p16e1 1x256x256 windowed";
        let qname = "gemv plam p16e1 1x256x256 fastquire";
        let win = bench
            .run(wname, || {
                gemm_bt_with_policy(&mode, &xe, &we, Some(&bt.data), &mut y, AccPolicy::Auto);
                black_box(&y);
            })
            .clone();
        let fq = bench
            .run(qname, || {
                gemm_bt_with_policy(
                    &mode,
                    &xe,
                    &we,
                    Some(&bt.data),
                    &mut y,
                    AccPolicy::ForceQuire,
                );
                black_box(&y);
            })
            .clone();
        println!(
            "  gemv  p16e1  windowed {:>12.0} MAC/s   fastquire {:>12.0} MAC/s   speedup {:.2}×",
            win.ops_per_sec(gemv_macs),
            fq.ops_per_sec(gemv_macs),
            bench.speedup(qname, wname).unwrap_or(1.0)
        );
    }

    // PJRT kernel artifact (Pallas PLAM GEMM), if built.
    #[cfg(feature = "pjrt")]
    {
        let path = std::path::Path::new("artifacts/plam_matmul_64.hlo.txt");
        if path.exists() {
            match plam::runtime::Runtime::cpu() {
                Ok(mut rt) => {
                    let exe = rt.load(path).unwrap();
                    let a: Vec<f32> = (0..64 * 64).map(|_| rng.normal() as f32).collect();
                    let b: Vec<f32> = (0..64 * 64).map(|_| rng.normal() as f32).collect();
                    let r = bench
                        .run("pjrt pallas plam_matmul 64³", || {
                            black_box(exe.run_f32(&[(&[64, 64], &a), (&[64, 64], &b)]).unwrap());
                        })
                        .clone();
                    println!(
                        "  pjrt kernel: {:>12.0} MAC/s (interpret-mode Pallas — structure, not speed)",
                        r.ops_per_sec((64 * 64 * 64) as f64)
                    );
                }
                Err(e) => println!("pjrt unavailable: {e:#}"),
            }
        } else {
            println!("(artifacts missing — pjrt series skipped; run `make artifacts`)");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    {
        println!("(built without `--features pjrt` — pjrt series skipped)");
    }

    bench
        .write_json("gemm_formats")
        .expect("write BENCH_gemm_formats.json");
}
