//! Bench: GEMM throughput across arithmetic formats — the software-
//! emulation ablation behind Table II's cost story (float32 vs exact
//! posit vs PLAM, quire vs f32 accumulation), plus the AOT PJRT kernel
//! when artifacts are present.
//!
//! Run: cargo bench --bench gemm_formats

use plam::bench::{black_box, Bench};
use plam::nn::{ArithMode, Layer, Tensor};
use plam::posit::PositFormat;
use plam::prng::Rng;

fn random_tensor(shape: &[usize], rng: &mut Rng) -> Tensor {
    Tensor::from_vec(
        shape,
        (0..shape.iter().product::<usize>())
            .map(|_| rng.normal() as f32 * 0.5)
            .collect(),
    )
}

fn main() {
    let mut bench = Bench::new();
    let mut rng = Rng::new(5);

    // Dense layer (out=128, in=256): one ISOLET-scale matvec per call.
    let layer = Layer::Dense {
        w: random_tensor(&[128, 256], &mut rng),
        b: random_tensor(&[128], &mut rng),
    };
    let x = random_tensor(&[256], &mut rng);
    let macs = 128 * 256;

    let modes = [
        ("float32", ArithMode::float32()),
        ("posit16-exact", ArithMode::posit_exact(PositFormat::P16E1)),
        ("posit16-plam", ArithMode::posit_plam(PositFormat::P16E1)),
    ];
    println!("dense 256→128 ({macs} MACs):");
    let mut results = vec![];
    for (name, mode) in &modes {
        let r = bench
            .run(&format!("dense {name}"), || {
                black_box(layer.forward(&x, mode));
            })
            .clone();
        results.push((name.to_string(), r));
    }
    println!("\nMAC throughput:");
    for (name, r) in &results {
        println!("  {:<16} {:>12.0} MAC/s", name, r.ops_per_sec(macs as f64));
    }
    let slowdown = |a: usize, b: usize| {
        results[a].1.mean.as_secs_f64() / results[b].1.mean.as_secs_f64()
    };
    println!(
        "  PLAM vs exact posit: {:.2}× faster (software analogue of the mult removal)",
        slowdown(1, 2)
    );

    // Prepared-model ablation: weights pre-encoded once (perf pass) —
    // measured on a single-Dense model so the series is comparable.
    use plam::nn::{Model, PreparedModel};
    let dense_model = Model {
        name: "bench-dense".into(),
        layers: vec![layer.clone()],
        input_shape: vec![256],
    };
    for (name, mode) in &modes {
        let prepared = PreparedModel::new(&dense_model, mode.clone());
        let r = bench
            .run(&format!("dense {name} (prepared)"), || {
                black_box(prepared.forward(&x));
            })
            .clone();
        println!(
            "  {:<16} prepared: {:>12.0} MAC/s",
            name,
            r.ops_per_sec(macs as f64)
        );
    }

    // Conv layer (LeNet C1 shape).
    let conv = Layer::Conv2d {
        w: random_tensor(&[6, 1, 5, 5], &mut rng),
        b: random_tensor(&[6], &mut rng),
        stride: 1,
        pad: 2,
    };
    let img = random_tensor(&[1, 28, 28], &mut rng);
    for (name, mode) in &modes {
        bench.run(&format!("conv lenet-c1 {name}"), || {
            black_box(conv.forward(&img, mode));
        });
    }

    // PJRT kernel artifact (Pallas PLAM GEMM), if built.
    let path = std::path::Path::new("artifacts/plam_matmul_64.hlo.txt");
    if path.exists() {
        match plam::runtime::Runtime::cpu() {
            Ok(mut rt) => {
                let exe = rt.load(path).unwrap();
                let a: Vec<f32> = (0..64 * 64).map(|_| rng.normal() as f32).collect();
                let b: Vec<f32> = (0..64 * 64).map(|_| rng.normal() as f32).collect();
                let r = bench
                    .run("pjrt pallas plam_matmul 64³", || {
                        black_box(exe.run_f32(&[(&[64, 64], &a), (&[64, 64], &b)]).unwrap());
                    })
                    .clone();
                println!(
                    "  pjrt kernel: {:>12.0} MAC/s (interpret-mode Pallas — structure, not speed)",
                    r.ops_per_sec((64 * 64 * 64) as f64)
                );
            }
            Err(e) => println!("pjrt unavailable: {e:#}"),
        }
    } else {
        println!("(artifacts missing — pjrt series skipped; run `make artifacts`)");
    }
}
