//! Microbenchmarks of the posit substrate: decode/encode, exact mul,
//! PLAM mul, quire MAC, conversions. The software-emulation analogue of
//! the paper's per-unit synthesis numbers — the interesting ratio is
//! PLAM vs exact (the fraction-multiplier removal shows up as fewer
//! integer ops on the software path too).
//!
//! Run: cargo bench --bench posit_ops   (PLAM_BENCH_FAST=1 for smoke)

use plam::bench::{black_box, Bench};
use plam::posit::{self, tables::DecodeTable, PositFormat, Quire};
use plam::prng::Rng;

fn operands(fmt: PositFormat, n: usize, seed: u64) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| loop {
            let b = rng.next_u64() & fmt.mask();
            if b != fmt.nar() {
                break b;
            }
        })
        .collect()
}

fn main() {
    let mut bench = Bench::new();
    const N: usize = 4096;

    for fmt in [PositFormat::P8E0, PositFormat::P16E1, PositFormat::P32E2] {
        let a = operands(fmt, N, 1);
        let b = operands(fmt, N, 2);

        let r = bench.run(&format!("decode {fmt} ×{N}"), || {
            for &x in &a {
                black_box(posit::decode(fmt, x));
            }
        });
        let decode_ops = r.ops_per_sec(N as f64);

        bench.run(&format!("exact mul {fmt} ×{N}"), || {
            for i in 0..N {
                black_box(posit::mul(fmt, a[i], b[i]));
            }
        });
        bench.run(&format!("PLAM mul {fmt} ×{N}"), || {
            for i in 0..N {
                black_box(posit::plam_mul(fmt, a[i], b[i]));
            }
        });
        bench.run(&format!("add {fmt} ×{N}"), || {
            for i in 0..N {
                black_box(posit::add(fmt, a[i], b[i]));
            }
        });
        bench.run(&format!("from_f64 {fmt} ×{N}"), || {
            for i in 0..N {
                black_box(posit::from_f64(fmt, i as f64 * 0.37 - 700.0));
            }
        });
        let _ = decode_ops;
    }

    // Quire MAC (the EMAC inner loop of the nn engine).
    let fmt = PositFormat::P16E1;
    let a = operands(fmt, N, 3);
    let b = operands(fmt, N, 4);
    let mut q = Quire::new(fmt);
    bench.run(&format!("quire exact MAC {fmt} ×{N}"), || {
        q.clear();
        for i in 0..N {
            q.mul_add(a[i], b[i]);
        }
        black_box(q.to_posit());
    });
    bench.run(&format!("quire PLAM MAC {fmt} ×{N}"), || {
        q.clear();
        for i in 0..N {
            q.plam_mul_add(a[i], b[i]);
        }
        black_box(q.to_posit());
    });

    // FastQuire MAC from pre-decoded entries — the actual nn hot loop
    // after the perf pass (decode table + u64 product + lazy limbs).
    {
        use plam::posit::FastQuire;
        let table = DecodeTable::new(fmt);
        let da: Vec<_> = a.iter().map(|&x| table.get(x)).collect();
        let db: Vec<_> = b.iter().map(|&x| table.get(x)).collect();
        let mut fq = FastQuire::new(fmt);
        bench.run(&format!("fast-quire exact MAC {fmt} ×{N} (pre-decoded)"), || {
            fq.clear();
            for i in 0..N {
                let (x, y) = (&da[i], &db[i]);
                if x.is_zero() || y.is_zero() || x.is_nar() || y.is_nar() {
                    continue;
                }
                let sig = (x.significand() as u64) * (y.significand() as u64);
                let scale = x.scale as i32 + y.scale as i32 - 60;
                fq.add_product64(sig, scale, x.sign ^ y.sign);
            }
            black_box(fq.to_posit());
        });
    }

    // Table-driven decode (the inference hot path).
    let table = DecodeTable::new(fmt);
    bench.run(&format!("table decode {fmt} ×{N}"), || {
        for &x in &a {
            black_box(table.get(x));
        }
    });

    println!("\n== summary (ops/s) ==");
    for r in bench.results() {
        println!("{:<44} {:>14.0}", r.name, r.ops_per_sec(N as f64));
    }

    bench
        .write_json("posit_ops")
        .expect("write BENCH_posit_ops.json");
}
