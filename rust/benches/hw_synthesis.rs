//! Bench: regenerate every hardware artifact of the paper's §V — Table
//! III, Fig. 1, Fig. 5, Fig. 6, headline — and time the cost model
//! itself (it must stay interactive for design-space sweeps).
//!
//! Run: cargo bench --bench hw_synthesis

use plam::bench::{black_box, Bench};
use plam::hardware;

fn main() {
    // The deliverable: print each table/figure once.
    println!("{}", hardware::render_table3());
    println!("{}", hardware::render_fig1());
    println!("{}", hardware::render_fig5());
    println!("{}", hardware::render_fig6());
    println!("{}", hardware::render_headline());

    // And a design-space sweep ablation: PLAM savings across <n, es>.
    println!("PLAM savings sweep (area/power vs exact posit, min-delay corner):");
    println!("{:>4} {:>3} {:>10} {:>10} {:>10}", "n", "es", "area", "power", "delay");
    for n in [8u32, 16, 24, 32] {
        for es in [0u32, 1, 2, 3] {
            let e = hardware::exact_posit_multiplier(
                "e", n, es, hardware::DecodeArch::LzdOnly, hardware::Rounding::Rne, false,
            )
            .synth();
            let p = hardware::plam_multiplier("p", n, es).synth();
            println!(
                "{:>4} {:>3} {:>9.1}% {:>9.1}% {:>9.1}%",
                n,
                es,
                (1.0 - p.area_um2 / e.area_um2) * 100.0,
                (1.0 - p.power_mw / e.power_mw) * 100.0,
                (1.0 - p.delay_ns / e.delay_ns) * 100.0
            );
        }
    }
    println!();

    // Timing: full model regeneration speed.
    let mut bench = Bench::new();
    bench.run("table3 (12 syntheses)", || {
        black_box(hardware::table3(16));
        black_box(hardware::table3(32));
    });
    bench.run("fig5 (7 syntheses)", || {
        black_box(hardware::fig5());
    });
    bench.run("fig6 (35 constrained syntheses)", || {
        black_box(hardware::fig6(16, &hardware::fig6_default_constraints(16)));
        black_box(hardware::fig6(32, &hardware::fig6_default_constraints(32)));
    });
    bench.run("headline", || {
        black_box(hardware::headline());
    });

    bench
        .write_json("hw_synthesis")
        .expect("write BENCH_hw_synthesis.json");
}
