//! Bench: E1 — the §III.C error analysis at scale (analytic surface +
//! bit-level measurement throughput across formats).
//!
//! Run: cargo bench --bench error_sweep

use plam::bench::{black_box, Bench};
use plam::experiments::{error_sweep, measured_error};
use plam::posit::PositFormat;

fn main() {
    // The deliverable numbers.
    let s = error_sweep(1024);
    println!(
        "analytic Eq.24 surface 1024²: max {:.6} ({:.4}%) at ({:.3},{:.3}), mean {:.4}%\n",
        s.max,
        s.max * 100.0,
        s.argmax.0,
        s.argmax.1,
        s.mean * 100.0
    );
    for (fmt, name) in [
        (PositFormat::P8E0, "posit<8,0>"),
        (PositFormat::P16E1, "posit<16,1>"),
        (PositFormat::P16E2, "posit<16,2>"),
        (PositFormat::P32E2, "posit<32,2>"),
    ] {
        let m = measured_error(fmt, 300_000, 17);
        println!(
            "{name:<12} 300k random pairs: max {:.4}% mean {:.4}% (bound 11.1111%)",
            m.max * 100.0,
            m.mean * 100.0
        );
    }
    println!();

    // Timing.
    let mut bench = Bench::new();
    bench.run("error_sweep 256²", || {
        black_box(error_sweep(256));
    });
    bench.run("measured_error p16e1 10k pairs", || {
        black_box(measured_error(PositFormat::P16E1, 10_000, 3));
    });

    bench
        .write_json("error_sweep")
        .expect("write BENCH_error_sweep.json");
}
