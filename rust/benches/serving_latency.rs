//! Bench: serving latency under open-loop load against the event-loop
//! front-end.
//!
//! Closed-loop driving (send, wait, send) hides queueing collapse: a
//! saturated server slows its clients down, so the measured rate
//! self-limits. Here requests follow a fixed arrival schedule
//! (request k fires at `t0 + k/rate`) regardless of how fast responses
//! come back, and latency is measured from the *scheduled* arrival —
//! queueing delay counts. The sweep reports p50/p95/p99 per offered
//! rate plus the throughput knee (highest offered rate the server
//! sustains at ≥ 0.9× achieved/offered).
//!
//! Exports BENCH_serving.json for ci/check_bench_regression.py. The
//! rate grid is fixed (fast mode shortens duration and connection
//! count only) so series names stay stable for the baseline.
//!
//! Two sweeps run back to back:
//!
//! * the original grid against a `loop_shards = 1` server, keeping the
//!   historical `serving open-loop …` series comparable across PRs;
//! * a shard sweep (1/2/4 loop shards, fresh server each) exporting
//!   `serving open-loop p50/p99 @500rps shards={n}` and `serving knee
//!   period shards={n}`. The knee-period series feed the soft
//!   4-shards-vs-1 scaling self-check in ci/bench_baseline.json, and a
//!   self-check series missing from the results is a *hard* guard
//!   failure — so the knee period is always recorded, falling back to
//!   the achieved-rate period at the lowest offered rate when no rate
//!   on the grid was sustained.
//!
//! Run: cargo bench --bench serving_latency

use std::net::TcpStream;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use plam::bench::{black_box, Bench};
use plam::coordinator::{serve, wire, BatcherConfig, Client, NnBackend, Router, ServerConfig};
use plam::nn::{ArithMode, Model, ModelKind};
use plam::prng::Rng;

const INPUT_LEN: usize = 617;

fn percentile(sorted: &[Duration], p: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

/// Drive `rate` req/s split round-robin across `conns` pipelined
/// connections for `duration`. Each connection runs a writer thread
/// (paces the schedule, streams request frames) and a reader thread
/// (responses come back in order; the schedule instants cross over an
/// mpsc channel). Returns (latencies, achieved req/s).
fn open_loop(
    addr: std::net::SocketAddr,
    route: &str,
    rate: u32,
    conns: usize,
    duration: Duration,
) -> (Vec<Duration>, f64) {
    let total = (rate as f64 * duration.as_secs_f64()).round() as usize;
    let period = Duration::from_secs_f64(1.0 / rate as f64);
    // Small lead time so every connection is set up before t0.
    let start = Instant::now() + Duration::from_millis(50);
    let mut handles = vec![];
    for c in 0..conns {
        let route = route.to_string();
        handles.push(std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            stream.set_nodelay(true).unwrap();
            let mut wtr = stream.try_clone().unwrap();
            let (tx, rx) = mpsc::channel::<Instant>();
            let n_mine = (c..total).step_by(conns).count();
            let writer = std::thread::spawn(move || {
                let input = vec![0.1f32; INPUT_LEN];
                let mut k = c;
                while k < total {
                    let at = start + period * k as u32;
                    loop {
                        let now = Instant::now();
                        if now >= at {
                            break;
                        }
                        std::thread::sleep((at - now).min(Duration::from_micros(200)));
                    }
                    // Latency clock starts at the SCHEDULED instant: if
                    // this writer falls behind, that lag is queueing
                    // delay the client experienced.
                    tx.send(at).unwrap();
                    wire::write_request(
                        &mut wtr,
                        &wire::Request {
                            model: route.clone(),
                            input: input.clone(),
                        },
                    )
                    .unwrap();
                    k += conns;
                }
            });
            let mut rdr = stream;
            let mut lats = Vec::with_capacity(n_mine);
            for _ in 0..n_mine {
                let at = rx.recv().unwrap();
                let out = wire::read_response(&mut rdr)
                    .expect("read response")
                    .expect("server-side success");
                assert_eq!(out.len(), 26);
                lats.push(Instant::now().saturating_duration_since(at));
            }
            writer.join().unwrap();
            lats
        }));
    }
    let mut lats: Vec<Duration> = Vec::with_capacity(total);
    for h in handles {
        lats.extend(h.join().unwrap());
    }
    let elapsed = Instant::now().saturating_duration_since(start);
    let achieved = lats.len() as f64 / elapsed.as_secs_f64();
    (lats, achieved)
}

/// One per-rate row of a sweep plus the knee bookkeeping.
struct SweepPoint {
    rate: u32,
    achieved: f64,
    p50: Duration,
    p95: Duration,
    p99: Duration,
}

/// Drive every rate on the grid and report the per-rate percentiles
/// plus the knee (highest offered rate with achieved ≥ 0.9× offered).
fn rate_sweep(
    addr: std::net::SocketAddr,
    rates: &[u32],
    conns: usize,
    duration: Duration,
) -> (Vec<SweepPoint>, Option<u32>) {
    let mut points = Vec::with_capacity(rates.len());
    let mut knee = None;
    println!(
        "{:>10} {:>12} {:>10} {:>10} {:>10}",
        "offered", "achieved", "p50 µs", "p95 µs", "p99 µs"
    );
    for &rate in rates {
        let (mut lats, achieved) = open_loop(addr, "m", rate, conns, duration);
        lats.sort();
        let (p50, p95, p99) = (
            percentile(&lats, 0.50),
            percentile(&lats, 0.95),
            percentile(&lats, 0.99),
        );
        println!(
            "{:>7}rps {:>9.1}rps {:>10} {:>10} {:>10}",
            rate,
            achieved,
            p50.as_micros(),
            p95.as_micros(),
            p99.as_micros()
        );
        if achieved >= 0.9 * rate as f64 {
            knee = Some(rate);
        }
        points.push(SweepPoint { rate, achieved, p50, p95, p99 });
    }
    (points, knee)
}

/// The knee as a period (ns per request, smaller = better). Always
/// produces a value: when no rate on the grid was sustained, falls back
/// to the achieved rate at the lowest offered rate so the self-check
/// series is never missing from the results.
fn knee_period(points: &[SweepPoint], knee: Option<u32>) -> Duration {
    let rps = match knee {
        Some(k) => k as f64,
        None => points.first().map_or(1.0, |p| p.achieved).max(1.0),
    };
    Duration::from_nanos((1e9 / rps) as u64)
}

/// Fresh server for one sweep: same model, router, and worker count
/// every time, only the loop-shard count varies.
fn start_server(loop_shards: usize) -> plam::coordinator::server::ServerHandle {
    let mut rng = Rng::new(7);
    let model = Model::init(ModelKind::MlpIsolet, &mut rng);
    let mut router = Router::new();
    router.register(
        "m",
        Arc::new(NnBackend::new(model, ArithMode::float32())),
        BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(1),
        },
    );
    serve(
        router,
        &ServerConfig {
            workers: 2,
            loop_shards,
            ..ServerConfig::default()
        },
    )
    .unwrap()
}

fn main() {
    let fast = std::env::var("PLAM_BENCH_FAST").is_ok();
    let (conns, duration) = if fast {
        (4usize, Duration::from_millis(400))
    } else {
        (8usize, Duration::from_secs(2))
    };
    // Fixed rate grid in both modes: series names feed the regression
    // baseline and must not depend on PLAM_BENCH_FAST.
    let rates: [u32; 4] = [250, 500, 1000, 2000];

    // The historical sweep is pinned to one loop shard so its series
    // stay comparable with pre-shard baselines.
    let h = start_server(1);

    let mut bench = Bench::new();

    // Closed-loop round trip: one connection, send-wait-send. This is
    // the machine-speed calibration series for the regression guard.
    let mut cl = Client::connect(h.addr).unwrap();
    let input = vec![0.1f32; INPUT_LEN];
    bench.run("serving closed-loop rtt", || {
        black_box(cl.infer("m", &input).unwrap());
    });
    drop(cl);

    println!("\nopen-loop sweep ({conns} connections, {duration:?} per rate):");
    let (points, knee) = rate_sweep(h.addr, &rates, conns, duration);
    for p in &points {
        bench.record(&format!("serving open-loop p50 @{}rps", p.rate), p.p50);
        bench.record(&format!("serving open-loop p95 @{}rps", p.rate), p.p95);
        bench.record(&format!("serving open-loop p99 @{}rps", p.rate), p.p99);
    }
    // The knee is exported as a *period* (ns per request at the highest
    // sustained rate) so that, like every other series, smaller = better.
    match knee {
        Some(k) => {
            println!("throughput knee: sustains {k} rps (achieved ≥ 0.9× offered)");
            bench.record(
                "serving knee period",
                Duration::from_nanos((1e9 / k as f64) as u64),
            );
        }
        None => println!("throughput knee: below {} rps on this machine", rates[0]),
    }

    let m = &h.router().get("m").unwrap().metrics;
    println!("server metrics: {}", m.summary());
    h.shutdown();

    // Shard sweep: same load, fresh server per loop-shard count. The
    // grid starts where the single-shard knee typically sits so the
    // scaling shows up as sustained rates, not just latency.
    let shard_rates: [u32; 4] = [500, 1000, 2000, 4000];
    for shards in [1usize, 2, 4] {
        let h = start_server(shards);
        println!("\nopen-loop shard sweep (shards={shards}, {conns} connections):");
        let (points, knee) = rate_sweep(h.addr, &shard_rates, conns, duration);
        let at500 = points.iter().find(|p| p.rate == 500).unwrap();
        bench.record(&format!("serving open-loop p50 @500rps shards={shards}"), at500.p50);
        bench.record(&format!("serving open-loop p99 @500rps shards={shards}"), at500.p99);
        let period = knee_period(&points, knee);
        match knee {
            Some(k) => println!("shards={shards}: sustains {k} rps"),
            None => println!(
                "shards={shards}: no grid rate sustained; knee period falls back to \
                 achieved rate at {} rps offered",
                shard_rates[0]
            ),
        }
        bench.record(&format!("serving knee period shards={shards}"), period);
        println!(
            "server metrics: {}",
            h.router().get("m").unwrap().metrics.summary()
        );
        h.shutdown();
    }

    bench.write_json("serving").expect("write BENCH_serving.json");
}
