//! Bench: end-to-end serving — latency/throughput of the L3 coordinator
//! under open-loop concurrent load, per arithmetic mode and batching
//! policy (the serving-side evaluation of DESIGN.md E8) — plus the
//! layer-boundary series: the encoded-activation pipeline vs the f32
//! round-trip path on multi-layer forward passes (guarded by
//! ci/check_bench_regression.py once exported).
//!
//! Run: cargo bench --bench e2e_inference

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use plam::bench::{black_box, Bench};
use plam::coordinator::{serve, BatcherConfig, Client, NnBackend, Router, ServerConfig};
use plam::nn::{
    ActivationPipeline, ArithMode, FormatPlan, Model, ModelKind, PreparedModel, Tensor,
};
use plam::posit::PositFormat;
use plam::prng::Rng;

fn drive(addr: std::net::SocketAddr, route: &str, clients: usize, per_client: usize) -> (f64, Duration) {
    let t0 = Instant::now();
    let mut joins = vec![];
    for c in 0..clients {
        let route = route.to_string();
        joins.push(std::thread::spawn(move || {
            let mut cl = Client::connect(addr).unwrap();
            let mut rng = Rng::new(c as u64 + 1);
            for _ in 0..per_client {
                let x: Vec<f32> = (0..617).map(|_| rng.normal() as f32 * 0.5).collect();
                cl.infer(&route, &x).unwrap();
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let dt = t0.elapsed();
    ((clients * per_client) as f64 / dt.as_secs_f64(), dt)
}

fn main() {
    let fast = std::env::var("PLAM_BENCH_FAST").is_ok();
    let per_client = if fast { 8 } else { 64 };
    let mut rng = Rng::new(42);
    let model = Model::init(ModelKind::MlpIsolet, &mut rng);
    // Open-loop driving doesn't fit Bench::run's closed-loop
    // calibration, so per-request means are recorded directly.
    let mut bench = Bench::new();

    println!("serving throughput (ISOLET MLP, 4 concurrent clients):");
    println!(
        "{:<16} {:>12} {:>10} {:>10} {:>11}",
        "mode", "req/s", "p50 µs", "p99 µs", "mean batch"
    );
    for (name, mode) in [
        ("float32", ArithMode::float32()),
        ("posit16-exact", ArithMode::posit_exact(PositFormat::P16E1)),
        ("posit16-plam", ArithMode::posit_plam(PositFormat::P16E1)),
    ] {
        let mut router = Router::new();
        router.register(
            "m",
            Arc::new(NnBackend::new(model.clone(), mode)),
            BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(2),
            },
        );
        let h = serve(
            router,
            &ServerConfig::default(),
        )
        .unwrap();
        let (rps, dt) = drive(h.addr, "m", 4, per_client);
        let b = h.router().get("m").unwrap();
        println!(
            "{:<16} {:>12.1} {:>10} {:>10} {:>11.2}",
            name,
            rps,
            b.metrics.latency_percentile_us(0.5).unwrap_or(0),
            b.metrics.latency_percentile_us(0.99).unwrap_or(0),
            b.metrics.mean_batch_size(),
        );
        // Inverse throughput (wall time per completed request across 4
        // concurrent clients) — NOT per-request latency; the latency
        // percentiles live in b.metrics above.
        bench.record(
            &format!("serve {name} inverse-throughput (4 clients)"),
            dt / (4 * per_client) as u32,
        );
        h.shutdown();
    }

    // Batching-policy ablation (PLAM mode): window size vs latency.
    println!("\nbatching policy ablation (posit16-plam):");
    println!(
        "{:<26} {:>12} {:>10} {:>10} {:>11}",
        "policy", "req/s", "p50 µs", "p99 µs", "mean batch"
    );
    for (label, max_batch, wait_ms) in [
        ("no batching (1, 0ms)", 1usize, 0u64),
        ("batch 8, 1ms", 8, 1),
        ("batch 16, 2ms", 16, 2),
        ("batch 32, 5ms", 32, 5),
    ] {
        let mut router = Router::new();
        router.register(
            "m",
            Arc::new(NnBackend::new(
                model.clone(),
                ArithMode::posit_plam(PositFormat::P16E1),
            )),
            BatcherConfig {
                max_batch,
                max_wait: Duration::from_millis(wait_ms),
            },
        );
        let h = serve(
            router,
            &ServerConfig::default(),
        )
        .unwrap();
        let (rps, dt) = drive(h.addr, "m", 8, per_client);
        let b = h.router().get("m").unwrap();
        println!(
            "{:<26} {:>12.1} {:>10} {:>10} {:>11.2}",
            label,
            rps,
            b.metrics.latency_percentile_us(0.5).unwrap_or(0),
            b.metrics.latency_percentile_us(0.99).unwrap_or(0),
            b.metrics.mean_batch_size(),
        );
        bench.record(
            &format!("policy {label} inverse-throughput (8 clients)"),
            dt / (8 * per_client) as u32,
        );
        assert_eq!(
            b.metrics.failed.load(Ordering::Relaxed),
            0,
            "failures under load"
        );
        h.shutdown();
    }

    // Layer-boundary series: the encoded-activation pipeline (planes
    // end to end, f32 only at the model boundary) vs the f32 round-trip
    // path (round every layer output to a posit, convert to f32,
    // re-encode at the next layer). Outputs are bit-identical — this
    // measures pure boundary tax. The conv model is where the tax bites
    // hardest: the round-trip path materialises and re-encodes a full
    // im2col matrix per sample per conv layer.
    println!("\nencoded-activation pipeline vs f32 round-trip (forward_batch):");
    let lenet = Model::init(ModelKind::LeNet5 { in_ch: 1, in_hw: 28 }, &mut rng);
    let imgs: Vec<Tensor> = (0..4)
        .map(|_| Tensor::from_vec(&[1, 28, 28], (0..784).map(|_| rng.f32()).collect()))
        .collect();
    let isolet: Vec<Tensor> = (0..16)
        .map(|_| {
            Tensor::from_vec(&[617], (0..617).map(|_| rng.normal() as f32 * 0.5).collect())
        })
        .collect();
    for (label, mode) in [
        ("lenet5 plam p16e1", ArithMode::posit_plam(PositFormat::P16E1)),
        ("lenet5 exact p16e1", ArithMode::posit_exact(PositFormat::P16E1)),
        ("lenet5 plam p8e0", ArithMode::posit_plam(PositFormat::P8E0)),
    ] {
        let enc = PreparedModel::new(&lenet, mode.clone());
        let rt = PreparedModel::new(&lenet, mode).with_pipeline(ActivationPipeline::F32Roundtrip);
        bench.run(&format!("{label} encoded"), || {
            black_box(enc.forward_batch(black_box(&imgs)));
        });
        bench.run(&format!("{label} roundtrip"), || {
            black_box(rt.forward_batch(black_box(&imgs)));
        });
        if let Some(s) =
            bench.speedup(&format!("{label} roundtrip"), &format!("{label} encoded"))
        {
            println!("  {label}: encoded speedup over round-trip {s:.2}x");
        }
    }
    {
        let mode = ArithMode::posit_plam(PositFormat::P16E1);
        let enc = PreparedModel::new(&model, mode.clone());
        let rt = PreparedModel::new(&model, mode).with_pipeline(ActivationPipeline::F32Roundtrip);
        bench.run("mlp-isolet plam p16e1 encoded", || {
            black_box(enc.forward_batch(black_box(&isolet)));
        });
        bench.run("mlp-isolet plam p16e1 roundtrip", || {
            black_box(rt.forward_batch(black_box(&isolet)));
        });
        let s = bench.speedup(
            "mlp-isolet plam p16e1 roundtrip",
            "mlp-isolet plam p16e1 encoded",
        );
        if let Some(s) = s {
            println!("  mlp-isolet plam p16e1: encoded speedup over round-trip {s:.2}x");
        }
    }

    // Mixed-format plans (per-layer formats with plane-domain recoding
    // at the boundaries): latency, encoded weight bytes, and a cheap
    // accuracy proxy (top-1 agreement with the float32 reference on a
    // random probe set) per plan. The uniform-P16E1 plan runs exactly
    // the model-global path — its series doubles as the "plan plumbing
    // must not slow the uniform case" guard in ci/bench_baseline.json;
    // first-last-wide adds two plane recodes per forward pass.
    println!("\nmixed-format plans (LeNet-5 forward_batch, PLAM):");
    println!(
        "{:<38} {:>12} {:>12} {:>10}",
        "plan", "mean ms", "enc bytes", "f32 agree"
    );
    let probe: Vec<Tensor> = (0..32)
        .map(|_| Tensor::from_vec(&[1, 28, 28], (0..784).map(|_| rng.f32()).collect()))
        .collect();
    let f32_ref = PreparedModel::new(&lenet, ArithMode::float32());
    let ref_classes: Vec<usize> = probe.iter().map(|x| f32_ref.predict(x)).collect();
    for plan in [
        FormatPlan::Uniform(PositFormat::P16E1),
        FormatPlan::FirstLastWide {
            wide: PositFormat::P16E1,
            narrow: PositFormat::P8E0,
        },
        FormatPlan::Uniform(PositFormat::P8E0),
    ] {
        let base = plan.representative_format().unwrap();
        let pm = PreparedModel::with_plan(&lenet, ArithMode::posit_plam(base), &plan)
            .expect("plan resolves against LeNet-5");
        let series = format!("lenet5 plan {}", plan.name());
        let r = bench.run(&series, || {
            black_box(pm.forward_batch(black_box(&imgs)));
        });
        let mean_ms = r.mean.as_secs_f64() * 1e3;
        let agree = probe
            .iter()
            .zip(ref_classes.iter())
            .filter(|(x, &c)| pm.predict(x) == c)
            .count() as f64
            / probe.len() as f64;
        println!(
            "{:<38} {:>12.3} {:>12} {:>9.0}%",
            plan.name(),
            mean_ms,
            pm.encoded_bytes(),
            agree * 100.0
        );
    }

    bench
        .write_json("e2e_inference")
        .expect("write BENCH_e2e_inference.json");
}
