//! End-to-end driver (DESIGN.md E8): proves all layers compose on a
//! real small workload.
//!
//!   1. TRAIN   — f32 SGD training of the Table I ISOLET MLP on the
//!                synthetic corpus, logging the loss curve.
//!   2. QUANT   — posit<16,1> weight quantisation (the Table II models).
//!   3. SERVE   — L3 coordinator serves the model over TCP in three
//!                arithmetic modes (+ the AOT PJRT artifact if built),
//!                with dynamic batching.
//!   4. DRIVE   — concurrent clients push the full test set through
//!                every route; accuracy + latency/throughput reported.
//!
//! Run: cargo run --release --example end_to_end
//! (The PJRT route appears when `make artifacts` has been run.)

use std::sync::Arc;
use std::time::Instant;

use plam::coordinator::{serve, BatcherConfig, Client, NnBackend, Router, ServerConfig};
use plam::data::{Dataset, DatasetKind};
use plam::nn::{loader, model::train_mlp, ArithMode, Model, ModelKind};
use plam::posit::PositFormat;
use plam::prng::Rng;

fn main() -> anyhow::Result<()> {
    // ---- 1. TRAIN -------------------------------------------------------
    let mut rng = Rng::new(7);
    println!("=== 1. train: mlp-isolet (617-128-64-26) on synthetic ISOLET ===");
    let data = Dataset::generate(DatasetKind::Isolet, 2080, 520, 7);
    let mut model = Model::init(ModelKind::MlpIsolet, &mut rng);
    let t0 = Instant::now();
    let losses = train_mlp(
        &mut model,
        &data.train_x,
        &data.train_y,
        12,
        64,
        0.05,
        0.9,
        &mut rng,
    );
    println!("loss curve ({} epochs, {:.1?}):", losses.len(), t0.elapsed());
    for (e, l) in losses.iter().enumerate() {
        let bar = "#".repeat((l * 40.0 / losses[0].max(1e-9)) as usize);
        println!("  epoch {e:>2}  loss {l:.4}  {bar}");
    }

    // ---- 2. QUANT -------------------------------------------------------
    println!("\n=== 2. quantise weights to posit<16,1> ===");
    let mut pmodel = model.clone();
    loader::quantize_weights(&mut pmodel, PositFormat::P16E1);
    println!("model: {} parameters, {} MACs/inference", model.params(), model.macs());

    // ---- 3. SERVE -------------------------------------------------------
    println!("\n=== 3. serve via the L3 coordinator (dynamic batching) ===");
    let mut router = Router::new();
    let cfg = BatcherConfig {
        max_batch: 16,
        max_wait: std::time::Duration::from_millis(2),
    };
    router.register(
        "isolet-f32",
        Arc::new(NnBackend::new(model.clone(), ArithMode::float32())),
        cfg,
    );
    router.register(
        "isolet-posit",
        Arc::new(NnBackend::new(
            pmodel.clone(),
            ArithMode::posit_exact(PositFormat::P16E1),
        )),
        cfg,
    );
    router.register(
        "isolet-plam",
        Arc::new(NnBackend::new(
            pmodel.clone(),
            ArithMode::posit_plam(PositFormat::P16E1),
        )),
        cfg,
    );
    #[allow(unused_mut)] // mutated only when the pjrt feature is on
    let mut routes = vec!["isolet-f32", "isolet-posit", "isolet-plam"];
    #[cfg(feature = "pjrt")]
    {
        let artifact = std::path::Path::new("artifacts/mlp_isolet_plam_b8.hlo.txt");
        if artifact.exists() {
            match plam::coordinator::PjrtBackend::load(artifact, 8, 617, 26) {
                Ok(be) => {
                    println!("PJRT artifact route up on {}", be.platform());
                    router.register("isolet-pjrt", Arc::new(be), cfg);
                    routes.push("isolet-pjrt");
                }
                Err(e) => println!("PJRT artifact skipped: {e:#}"),
            }
        } else {
            println!("(no artifacts/ — PJRT route skipped; run `make artifacts`)");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    {
        println!("(built without `--features pjrt` — PJRT route skipped)");
    }
    println!("routing table:\n{}", router.table());
    let handle = serve(
        router,
        &ServerConfig {
            // Two pool workers: enough to demonstrate sharded GEMM
            // batches without assuming a big machine.
            workers: 2,
            ..ServerConfig::default()
        },
    )?;
    println!("listening on {}", handle.addr);

    // ---- 4. DRIVE -------------------------------------------------------
    println!("\n=== 4. drive: full test set through every route, 4 clients ===");
    println!(
        "note: the PJRT route serves the *python-trained* baked weights and is\n\
         therefore evaluated on the python-exported test split; the nn routes\n\
         serve the rust-trained model on the rust-generated split.\n"
    );
    // Python-exported split for the artifact route (its training data).
    let py_testset = plam::experiments::load_exported_testset(
        std::path::Path::new("artifacts/weights/isolet_test.ptw"),
        DatasetKind::Isolet,
    );
    for route in &routes {
        let addr = handle.addr;
        let (xs, ys): (Vec<Vec<f32>>, Vec<usize>) = if *route == "isolet-pjrt" {
            let (pxs, pys) = py_testset.clone().expect("exported test set present");
            (pxs.into_iter().map(|t| t.data).collect(), pys)
        } else {
            (
                data.test_x.iter().map(|t| t.data.clone()).collect(),
                data.test_y.clone(),
            )
        };
        let n = xs.len();
        let t0 = Instant::now();
        let clients = 4;
        let chunk = n.div_ceil(clients);
        let mut joins = vec![];
        for c in 0..clients {
            let xs = xs.clone();
            let ys = ys.clone();
            let route = route.to_string();
            joins.push(std::thread::spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                let mut correct = 0usize;
                let lo = c * chunk;
                let hi = ((c + 1) * chunk).min(xs.len());
                for i in lo..hi {
                    let out = client.infer(&route, &xs[i]).unwrap();
                    let pred = out
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0;
                    correct += (pred == ys[i]) as usize;
                }
                correct
            }));
        }
        let correct: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        let dt = t0.elapsed();
        let b = handle.router().get(route)?;
        println!(
            "{route:<14} acc {:.4}  {:>7.1} req/s  p50 {:>6}µs  p99 {:>7}µs  mean batch {:.2}",
            correct as f64 / n as f64,
            n as f64 / dt.as_secs_f64(),
            b.metrics.latency_percentile_us(0.5).unwrap_or(0),
            b.metrics.latency_percentile_us(0.99).unwrap_or(0),
            b.metrics.mean_batch_size(),
        );
    }

    println!("\nend_to_end OK");
    handle.shutdown();
    Ok(())
}
