//! Regenerates the paper's hardware evaluation: Table III, Fig. 1,
//! Fig. 5, Fig. 6 and the headline reductions.
//!
//! Usage:
//!   cargo run --release --example hardware_report            # everything
//!   cargo run --release --example hardware_report -- --table3
//!   cargo run --release --example hardware_report -- --fig1 --fig5
//!   cargo run --release --example hardware_report -- --headline

use plam::hardware;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let all = args.is_empty();
    let has = |f: &str| all || args.iter().any(|a| a == f);

    if has("--table3") {
        println!("{}", hardware::render_table3());
    }
    if has("--fig1") {
        println!("{}", hardware::render_fig1());
    }
    if has("--fig5") {
        println!("{}", hardware::render_fig5());
    }
    if has("--fig6") {
        println!("{}", hardware::render_fig6());
    }
    if has("--headline") {
        println!("{}", hardware::render_headline());
    }
}
