//! Batched posit-DNN inference over the full three-layer stack.
//!
//! Starts the L3 server with two routes for the ISOLET MLP:
//!   `isolet-plam`       — pure-Rust engine, PLAM multiplier (quire EMAC)
//!   `isolet-plam-pjrt`  — the AOT-compiled L1/L2 artifact (Pallas PLAM
//!                         kernel inside the JAX graph), via PJRT
//! then sends the exported test set through both and reports agreement
//! and accuracy. Requires `make artifacts` (weights + HLO present).
//!
//! Run: cargo run --release --example dnn_inference

use std::path::Path;
use std::sync::Arc;

use plam::coordinator::{serve, BatcherConfig, Client, NnBackend, PjrtBackend, Router, ServerConfig};
use plam::data::DatasetKind;
use plam::experiments::load_exported_testset;
use plam::nn::{loader, ArithMode, Model, ModelKind};
use plam::posit::PositFormat;

fn main() -> anyhow::Result<()> {
    let weights = Path::new("artifacts/weights/isolet.ptw");
    let testset = Path::new("artifacts/weights/isolet_test.ptw");
    let artifact = Path::new("artifacts/mlp_isolet_plam_b8.hlo.txt");
    for p in [weights, testset, artifact] {
        if !p.exists() {
            eprintln!("missing {p:?} — run `make artifacts` first");
            std::process::exit(1);
        }
    }

    // Rust-native backend with the trained weights.
    let mut model = Model::new(ModelKind::MlpIsolet);
    loader::apply_weights(&mut model, &loader::load_weights(weights)?)?;
    let mut router = Router::new();
    router.register(
        "isolet-plam",
        Arc::new(NnBackend::new(
            model,
            ArithMode::posit_plam(PositFormat::P16E1),
        )),
        BatcherConfig::default(),
    );
    // AOT artifact backend (batch-8 static shape).
    let pjrt = PjrtBackend::load(artifact, 8, 617, 26)?;
    println!("PJRT backend up on {}", pjrt.platform());
    router.register("isolet-plam-pjrt", Arc::new(pjrt), BatcherConfig::default());

    let handle = serve(
        router,
        &ServerConfig {
            workers: 2,
            ..ServerConfig::default()
        },
    )?;
    println!("server on {}\n", handle.addr);

    let (xs, ys) = load_exported_testset(testset, DatasetKind::Isolet).unwrap();
    let n = xs.len().min(200);
    let mut client = Client::connect(handle.addr)?;

    let mut agree = 0usize;
    let mut correct_rust = 0usize;
    let mut correct_pjrt = 0usize;
    let t0 = std::time::Instant::now();
    for (x, &y) in xs.iter().zip(ys.iter()).take(n) {
        let rust_out = client.infer("isolet-plam", &x.data)?;
        let pjrt_out = client.infer("isolet-plam-pjrt", &x.data)?;
        let am = |v: &[f32]| {
            v.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        };
        let (pr, pp) = (am(&rust_out), am(&pjrt_out));
        agree += (pr == pp) as usize;
        correct_rust += (pr == y) as usize;
        correct_pjrt += (pp == y) as usize;
    }
    let dt = t0.elapsed();

    println!("samples:                 {n}");
    println!(
        "rust-engine accuracy:    {:.4}",
        correct_rust as f64 / n as f64
    );
    println!(
        "pjrt-artifact accuracy:  {:.4}",
        correct_pjrt as f64 / n as f64
    );
    println!(
        "argmax agreement:        {:.4}",
        agree as f64 / n as f64
    );
    println!(
        "wall time:               {:.2?} ({:.1} inferences/s across both routes)",
        dt,
        2.0 * n as f64 / dt.as_secs_f64()
    );
    for name in handle.router().model_names() {
        if let Ok(b) = handle.router().get(&name) {
            println!("{name}: {}", b.metrics.summary());
        }
    }
    handle.shutdown();
    Ok(())
}
