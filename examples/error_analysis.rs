//! E1 — the paper's §III.C approximation-error analysis:
//!   · the Eq. 24 error surface over (f_A, f_B) with its 11.1 % peak,
//!   · bit-level measured error for several formats,
//!   · the regime/exponent-independence property.
//!
//! Run: cargo run --release --example error_analysis

use plam::experiments::{error_sweep, measured_error, render_error_analysis};
use plam::posit::{plam_relative_error, PositFormat};

fn main() {
    println!("{}", render_error_analysis());

    // ASCII rendering of the Eq. 24 error surface (the figure the
    // paper describes in §III.C).
    println!("Eq. 24 relative-error surface (rows f_A, cols f_B, % of exact product):");
    let steps = 16;
    print!("      ");
    for j in 0..steps {
        print!("{:>5.2}", j as f64 / steps as f64);
    }
    println!();
    for i in 0..steps {
        let fa = i as f64 / steps as f64;
        print!("{fa:>5.2} ");
        for j in 0..steps {
            let fb = j as f64 / steps as f64;
            print!("{:>5.1}", plam_relative_error(fa, fb) * 100.0);
        }
        println!();
    }

    // Regime/exponent independence: same fractions, wildly different
    // scales → identical relative error.
    println!("\nregime/exponent independence (fractions 0.5/0.5 at different scales):");
    let fmt = PositFormat::P16E1;
    for (a, b) in [(1.5, 1.5), (3.0, 3.0), (1.5, 96.0), (0.046875, 1.5)] {
        let pa = plam::posit::from_f64(fmt, a);
        let pb = plam::posit::from_f64(fmt, b);
        let exact = plam::posit::to_f64(fmt, pa) * plam::posit::to_f64(fmt, pb);
        let approx = plam::posit::plam_value_f64(fmt, pa, pb);
        println!(
            "  {a:>9} × {b:>9}: exact {exact:>12.6}, PLAM {approx:>12.6}, rel err {:.4}%",
            (exact - approx) / exact * 100.0
        );
    }

    // Mean-error comparison across formats (decision margins argument:
    // mean error ~3.8 % ≪ typical softmax margins).
    println!("\nmean |rel err| over random operand pairs:");
    for (fmt, name) in [
        (PositFormat::P8E0, "posit<8,0> "),
        (PositFormat::P16E1, "posit<16,1>"),
        (PositFormat::P16E2, "posit<16,2>"),
        (PositFormat::P32E2, "posit<32,2>"),
    ] {
        let m = measured_error(fmt, 200_000, 9);
        println!("  {name}: mean {:.4}%  max {:.4}%", m.mean * 100.0, m.max * 100.0);
    }

    let s = error_sweep(1024);
    println!(
        "\nanalytic check: max {:.6} at ({:.3},{:.3}) — paper bound 1/9 = {:.6}",
        s.max,
        s.argmax.0,
        s.argmax.1,
        1.0 / 9.0
    );
}
