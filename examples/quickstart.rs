//! Quickstart: the public API in two minutes.
//!
//! Run: cargo run --release --example quickstart

use plam::hardware;
use plam::posit::{PositFormat, Quire, P16E1, P32E2};

fn main() {
    // --- 1. Posit arithmetic --------------------------------------------
    let a = P16E1::from_f64(1.5);
    let b = P16E1::from_f64(2.25);
    println!("posit<16,1> arithmetic:");
    println!("  {a} + {b} = {}", a + b);
    println!("  {a} × {b} = {}   (exact, Fig. 3 datapath)", a * b);
    println!("  {a} ×̃ {b} = {}   (PLAM,  Fig. 4 datapath)", a.plam_mul(b));

    // The Mitchell worst case: fractions 0.5 → 11.1 % error.
    let w = P16E1::from_f64(1.5);
    let exact = (w * w).to_f64();
    let approx = w.plam_mul(w).to_f64();
    println!(
        "  worst case 1.5×1.5: exact {exact}, PLAM {approx} → rel err {:.2}% (bound 11.1%)",
        (exact - approx) / exact * 100.0
    );

    // --- 2. Runtime-parameterised formats + quire ------------------------
    let fmt = PositFormat::new(12, 1); // any <n, es> up to 32 bits
    let x = plam::posit::from_f64(fmt, 3.14159);
    println!("\ncustom Posit<12,1>: 3.14159 → {:#06x} → {}", x, plam::posit::to_f64(fmt, x));

    let mut q = Quire::new(PositFormat::P16E1);
    for i in 1..=100 {
        let v = plam::posit::from_f64(PositFormat::P16E1, 1.0 / i as f64);
        q.mul_add(v, v); // Σ 1/i² with a single final rounding
    }
    println!(
        "quire Σ 1/i² (100 terms, one rounding): {} (π²/6 = {:.6})",
        plam::posit::to_f64(PositFormat::P16E1, q.to_posit()),
        std::f64::consts::PI * std::f64::consts::PI / 6.0
    );

    // --- 3. Hardware cost model ------------------------------------------
    let h = hardware::headline();
    println!("\nhardware model (32-bit PLAM vs exact posit multiplier [16]):");
    println!(
        "  area −{:.1}%   power −{:.1}%   (paper: −72.9% / −81.8%)",
        h.area_reduction_32 * 100.0,
        h.power_reduction_32 * 100.0
    );
    let plam32 = hardware::plam_multiplier("plam32", 32, 2).synth();
    println!(
        "  PLAM<32,2>: {} LUTs, {} DSPs, {:.0} µm², {:.3} mW, {:.3} ns",
        plam32.luts as u32, plam32.dsps, plam32.area_um2, plam32.power_mw, plam32.delay_ns
    );

    // --- 4. DNN inference in three formats --------------------------------
    let mut rng = plam::prng::Rng::new(1);
    let model = plam::nn::Model::init(plam::nn::ModelKind::MlpIsolet, &mut rng);
    let x = plam::nn::Tensor::from_vec(
        &[617],
        (0..617).map(|_| rng.normal() as f32 * 0.5).collect(),
    );
    println!("\nISOLET MLP ({} params) logits[0..4]:", model.params());
    for mode in [
        plam::nn::ArithMode::float32(),
        plam::nn::ArithMode::posit_exact(PositFormat::P16E1),
        plam::nn::ArithMode::posit_plam(PositFormat::P16E1),
    ] {
        let y = model.forward(&x, &mode);
        println!(
            "  {:<18} {:?}",
            mode.name(),
            &y.data[..4.min(y.data.len())]
        );
    }

    let _ = P32E2::ONE; // the 32-bit type is there too
    println!("\nquickstart OK — see examples/hardware_report.rs, dnn_inference.rs, end_to_end.rs");
}
