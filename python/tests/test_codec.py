"""positjax codec vs the pure-Python oracle (ref.py) — exhaustive for
Posit<8,0>, hypothesis-driven for Posit<16,1>."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.positjax import codec

N16, ES16 = 16, 1
N8, ES8 = 8, 0


def test_exhaustive_decode_p8():
    bits = jnp.arange(256, dtype=jnp.int32)
    vals = np.array(codec.to_f32(bits, N8, ES8))
    for b in range(256):
        want = ref.to_float(b, N8, ES8)
        if b == 0x80:
            assert np.isnan(vals[b])
        else:
            assert vals[b] == pytest.approx(want, rel=1e-6), f"bits={b:#x}"


def test_exhaustive_round_trip_p8():
    bits = jnp.arange(256, dtype=jnp.int32)
    vals = codec.to_f32(bits, N8, ES8)
    back = np.array(codec.from_f32(vals, N8, ES8))
    for b in range(256):
        if b == 0x80:
            continue  # NaN → NaR
        assert back[b] == b, f"bits={b:#x}"


def test_exhaustive_round_trip_p16():
    bits = jnp.arange(65536, dtype=jnp.int32)
    vals = codec.to_f32(bits, N16, ES16)
    back = np.array(codec.from_f32(vals, N16, ES16))
    ok = back == np.arange(65536)
    ok[0x8000] = True  # NaR → NaN → NaR handled below
    assert np.array(codec.from_f32(jnp.array([np.nan], jnp.float32), N16, ES16))[0] == 0x8000
    assert ok.all(), f"failures at {np.where(~ok)[0][:10]}"


@settings(max_examples=300, deadline=None)
@given(st.floats(allow_nan=False, allow_infinity=False, width=32))
def test_from_f32_matches_oracle(x):
    got = int(codec.from_f32(jnp.array([x], jnp.float32), N16, ES16)[0])
    want = ref.from_float(float(np.float32(x)), N16, ES16)
    assert got == want, f"x={x}"


@settings(max_examples=200, deadline=None)
@given(st.integers(0, 65535))
def test_decode_matches_oracle_p16(bits):
    cls, sign, scale, frac, fb = ref.decode(bits, N16, ES16)
    s, sc, fr = codec.decode(jnp.array([bits]), N16, ES16)
    if cls == "zero":
        assert int(sc[0]) == codec.SCALE_ZERO
    elif cls == "nar":
        assert int(sc[0]) == codec.SCALE_NAR
    else:
        assert int(s[0]) == sign
        assert int(sc[0]) == scale
        assert int(fr[0]) == frac << (codec.FRAC_W - fb)


def test_specials():
    assert int(codec.from_f32(jnp.array([0.0], jnp.float32), N16, ES16)[0]) == 0
    assert int(codec.from_f32(jnp.array([np.inf], jnp.float32), N16, ES16)[0]) == 0x8000
    assert np.isnan(np.array(codec.to_f32(jnp.array([0x8000]), N16, ES16))[0])
    assert np.array(codec.to_f32(jnp.array([0]), N16, ES16))[0] == 0.0


def test_saturation():
    big = codec.from_f32(jnp.array([1e30], jnp.float32), N16, ES16)
    assert int(big[0]) == codec.maxpos(N16)
    tiny = codec.from_f32(jnp.array([1e-30], jnp.float32), N16, ES16)
    assert int(tiny[0]) == codec.minpos(N16)
    # Negative saturation: two's complement of maxpos.
    nbig = codec.from_f32(jnp.array([-1e30], jnp.float32), N16, ES16)
    assert int(nbig[0]) == ((-codec.maxpos(N16)) & codec.mask(N16))


def test_quantize_idempotent():
    rng = np.random.default_rng(0)
    x = (rng.standard_normal(512) * 10 ** rng.uniform(-6, 6, 512)).astype(np.float32)
    q1 = np.array(codec.quantize_f32(x, N16, ES16))
    q2 = np.array(codec.quantize_f32(q1, N16, ES16))
    np.testing.assert_array_equal(q1, q2)


def test_subnormal_inputs_saturate_to_minpos():
    sub = np.float32(1e-40)  # f32 subnormal
    got = int(codec.from_f32(jnp.array([sub], jnp.float32), N16, ES16)[0])
    assert got == codec.minpos(N16)
