"""L2 model graphs: shapes, format parity, PTW round trip, AOT text."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model as L2
from compile import ptw, datasets


def test_mlp_shapes():
    params = L2.init_mlp_params("isolet", seed=0)
    x = np.zeros((8, 617), np.float32)
    for mul in ["float", "plam", "exact"]:
        out = np.array(L2.mlp_forward(params, x, mul=mul))
        assert out.shape == (8, 26), mul


def test_har_topology():
    params = L2.init_mlp_params("har", seed=1)
    x = np.zeros((8, 561), np.float32)
    out = np.array(L2.mlp_forward(params, x, name="har", mul="float"))
    assert out.shape == (8, 6)


def test_plam_close_to_float_on_trained_scale_weights():
    rng = np.random.default_rng(2)
    params = L2.init_mlp_params("isolet", seed=2)
    x = rng.standard_normal((8, 617)).astype(np.float32) * 0.5
    f = np.array(L2.mlp_forward(params, x, mul="float"))
    p = np.array(L2.mlp_forward(params, x, mul="plam"))
    e = np.array(L2.mlp_forward(params, x, mul="exact"))
    # Same argmax for the large majority of rows (random init logits are
    # close together, so demand 6/8 not 8/8).
    assert (f.argmax(1) == p.argmax(1)).sum() >= 6
    assert (e.argmax(1) == p.argmax(1)).sum() >= 6
    # Magnitudes comparable.
    assert np.abs(p).max() < np.abs(f).max() * 2 + 1.0


def test_ptw_round_trip(tmp_path):
    params = L2.init_mlp_params("isolet", seed=3)
    path = os.path.join(tmp_path, "w.ptw")
    ptw.save(path, params)
    back = ptw.load(path)
    assert set(back) == set(params)
    for k in params:
        np.testing.assert_array_equal(back[k], params[k])


def test_datasets_shapes():
    for name, (shape, classes, _) in datasets.SPECS.items():
        if len(shape) == 3 and shape[-1] == 32:
            n = 8  # keep image rendering cheap in unit tests
        else:
            n = 2 * classes
        tx, ty, vx, vy = datasets.generate(name, n, 4, seed=1)
        assert tx.shape == (n, *shape)
        assert ty.shape == (n,)
        assert vx.shape == (4, *shape)
        assert (ty < classes).all()


def test_aot_hlo_text_contains_full_constants():
    # The print_large_constants regression: a baked-weight graph's HLO
    # text must never elide constants as `{...}`.
    from compile.aot import to_hlo_text

    params = L2.init_mlp_params("isolet", seed=0)
    fn = L2.mlp_forward_fn(params, mul="float")
    low = jax.jit(fn).lower(jax.ShapeDtypeStruct((8, 617), jnp.float32))
    text = to_hlo_text(low)
    assert "{...}" not in text
    assert "f32[617,128]" in text


def test_training_one_epoch_reduces_loss():
    from compile import train

    params, vx, vy, hist = train.train_model(
        "isolet", epochs=2, train_n=260, test_n=52, seed=3, log=lambda s: None
    )
    assert hist[-1]["loss"] < hist[0]["loss"] * 1.01
    assert hist[-1]["test_acc"] > 1.0 / 26  # better than chance
