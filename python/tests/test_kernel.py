"""Pallas PLAM GEMM kernel vs the pure-Python oracle — the core L1
correctness signal (DESIGN.md §7), with hypothesis sweeping shapes and
value distributions."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.plam_matmul import plam_matmul, plam_matmul_padded
from compile.positjax import codec, plam


def assert_matches_ref(a, b):
    got = np.array(plam_matmul_padded(a, b))
    want = ref.plam_matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_small_identity():
    eye = np.eye(8, dtype=np.float32)
    out = np.array(plam_matmul(eye, eye))
    np.testing.assert_array_equal(out, eye)  # powers of two are PLAM-exact


def test_mitchell_worst_case():
    # 1.5 × 1.5 → 2.0 under PLAM (the 11.1 % worst case).
    a = np.full((8, 8), 1.5, np.float32)
    out = np.array(plam_matmul(a, a))
    np.testing.assert_allclose(out, np.full((8, 8), 8 * 2.0), rtol=1e-6)


def test_zeros_and_signs():
    a = np.zeros((8, 8), np.float32)
    b = np.ones((8, 8), np.float32)
    np.testing.assert_array_equal(np.array(plam_matmul(a, b)), a)
    c = -np.eye(8, dtype=np.float32)
    np.testing.assert_array_equal(np.array(plam_matmul(c, b)), -b)


@settings(max_examples=12, deadline=None)
@given(
    m=st.sampled_from([1, 3, 8]),
    k=st.sampled_from([1, 5, 16]),
    n=st.sampled_from([2, 8, 11]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([0.1, 1.0, 100.0]),
)
def test_matches_oracle_random(m, k, n, seed, scale):
    rng = np.random.default_rng(seed)
    a = (rng.standard_normal((m, k)) * scale).astype(np.float32)
    b = (rng.standard_normal((k, n)) * scale).astype(np.float32)
    assert_matches_ref(a, b)


def test_error_bound_against_exact_float():
    # Relative error of each PLAM product vs the real product is ≤ 1/9;
    # check through the kernel on a diagonal (products isolated).
    rng = np.random.default_rng(3)
    x = (rng.uniform(1.0, 2.0, 8)).astype(np.float32)
    a = np.diag(x).astype(np.float32)
    b = np.diag(x).astype(np.float32)
    got = np.diag(np.array(plam_matmul(a, b)))
    exact = x.astype(np.float64) ** 2
    rel = np.abs(exact - got) / exact
    assert rel.max() <= 1 / 9 + 1e-6


def test_exact_mul_mode_matches_oracle():
    rng = np.random.default_rng(5)
    a = rng.standard_normal((8, 8)).astype(np.float32)
    b = rng.standard_normal((8, 8)).astype(np.float32)
    got = np.array(plam_matmul(a, b, mul="exact"))
    # Oracle: quantise, exact posit products, f32 sum.
    want = np.zeros((8, 8), np.float32)
    abits = [[ref.from_float(float(a[i, p]), 16, 1) for p in range(8)] for i in range(8)]
    bbits = [[ref.from_float(float(b[p, j]), 16, 1) for j in range(8)] for p in range(8)]
    for i in range(8):
        for j in range(8):
            acc = np.float32(0)
            for p in range(8):
                prod = ref.to_float(ref.exact_mul(abits[i][p], bbits[p][j], 16, 1), 16, 1)
                acc = np.float32(acc + np.float32(prod))
            want[i, j] = acc
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 65535), st.integers(0, 65535))
def test_plam_mul_bitexact_vs_oracle(a, b):
    got = int(plam.plam_mul(jnp.array([a]), jnp.array([b]), 16, 1)[0])
    want = ref.plam_mul(a, b, 16, 1)
    assert got == want, f"a={a:#x} b={b:#x}"


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 65535), st.integers(0, 65535))
def test_exact_mul_bitexact_vs_oracle(a, b):
    got = int(plam.exact_mul(jnp.array([a]), jnp.array([b]), 16, 1)[0])
    want = ref.exact_mul(a, b, 16, 1)
    assert got == want, f"a={a:#x} b={b:#x}"


def test_plam_underestimates_exact():
    # |PLAM product| <= |exact product| always (log2(1+x) >= x).
    rng = np.random.default_rng(11)
    bits = rng.integers(1, 65536, size=(2, 500))
    bits = bits[:, (bits[0] != 0x8000) & (bits[1] != 0x8000)]
    a, b = jnp.array(bits[0]), jnp.array(bits[1])
    pl_v = np.abs(np.array(codec.to_f32(plam.plam_mul(a, b, 16, 1), 16, 1)))
    ex_v = np.abs(np.array(codec.to_f32(plam.exact_mul(a, b, 16, 1), 16, 1)))
    assert (pl_v <= ex_v * (1 + 1e-6) + 1e-30).all()
