"""Training pipeline (build-time): trains the paper's Table I models on
the synthetic datasets and exports weights + test splits as PTW files
for the Rust inference engine (Table II) and the AOT serving graphs.

Training runs in f32 JAX with hand-rolled Adam/SGD (per Table I's
optimiser column; optax is unavailable offline). The posit columns of
Table II evaluate the *posit-quantised* copies of these weights — the
same train-in-f32 / infer-in-posit flow as the paper's Deep Positron
lineage [8] (full in-posit training à la Deep PeNSieve is exercised at
unit scale by the Rust quire tests).

Usage:
  cd python && python -m compile.train --out-dir ../artifacts/weights \
      [--models isolet,har] [--epochs 20] [--train-n 2600]
"""

import argparse
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets, ptw

# ----------------------------------------------------------------------
# Model definitions (parameter names match rust/src/nn/model.rs indices).
# ----------------------------------------------------------------------


def init_params(model, rng):
    """He-uniform initial parameters, keyed 'layer{i}.w|b'."""

    def dense(i, o):
        bound = np.sqrt(6.0 / i)
        return (
            rng.uniform(-bound, bound, (o, i)).astype(np.float32),
            np.zeros((o,), np.float32),
        )

    def conv(oc, ic, k):
        bound = np.sqrt(6.0 / (ic * k * k))
        return (
            rng.uniform(-bound, bound, (oc, ic, k, k)).astype(np.float32),
            np.zeros((oc,), np.float32),
        )

    p = {}
    if model == "isolet":
        for li, (i, o) in zip([0, 2, 4], [(617, 128), (128, 64), (64, 26)]):
            p[f"layer{li}.w"], p[f"layer{li}.b"] = dense(i, o)
    elif model == "har":
        for li, (i, o) in zip([0, 2, 4], [(561, 512), (512, 512), (512, 6)]):
            p[f"layer{li}.w"], p[f"layer{li}.b"] = dense(i, o)
    elif model in ("mnist", "svhn"):
        ic = 1 if model == "mnist" else 3
        p["layer0.w"], p["layer0.b"] = conv(6, ic, 5)
        p["layer3.w"], p["layer3.b"] = conv(16, 6, 5)
        for li, (i, o) in zip([7, 9, 11], [(400, 120), (120, 84), (84, 10)]):
            p[f"layer{li}.w"], p[f"layer{li}.b"] = dense(i, o)
    elif model == "cifar10":
        p["layer0.w"], p["layer0.b"] = conv(64, 3, 5)
        p["layer3.w"], p["layer3.b"] = conv(64, 64, 5)
        for li, (i, o) in zip([7, 9, 11], [(64 * 8 * 8, 384), (384, 192), (192, 10)]):
            p[f"layer{li}.w"], p[f"layer{li}.b"] = dense(i, o)
    else:
        raise ValueError(model)
    return p


def _conv(x, w, b, pad):
    """NCHW conv, stride 1, symmetric padding — matches the Rust layer."""
    out = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out + b[None, :, None, None]


def _maxpool(x, k=2, s=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, k, k), (1, 1, s, s), "VALID"
    )


def forward(model, params, x):
    """Batch logits for any of the five models (f32 training graph)."""
    relu = jax.nn.relu
    if model in ("isolet", "har"):
        h = x
        for li in [0, 2, 4]:
            h = h @ params[f"layer{li}.w"].T + params[f"layer{li}.b"]
            if li != 4:
                h = relu(h)
        return h
    if model in ("mnist", "svhn"):
        pad = 2 if model == "mnist" else 0
        h = relu(_conv(x, params["layer0.w"], params["layer0.b"], pad))
        h = _maxpool(h)
        h = relu(_conv(h, params["layer3.w"], params["layer3.b"], 0))
        h = _maxpool(h)
        h = h.reshape(h.shape[0], -1)
        for li in [7, 9, 11]:
            h = h @ params[f"layer{li}.w"].T + params[f"layer{li}.b"]
            if li != 11:
                h = relu(h)
        return h
    if model == "cifar10":
        h = relu(_conv(x, params["layer0.w"], params["layer0.b"], 2))
        h = _maxpool(h)
        h = relu(_conv(h, params["layer3.w"], params["layer3.b"], 2))
        h = _maxpool(h)
        h = h.reshape(h.shape[0], -1)
        for li in [7, 9, 11]:
            h = h @ params[f"layer{li}.w"].T + params[f"layer{li}.b"]
            if li != 11:
                h = relu(h)
        return h
    raise ValueError(model)


# ----------------------------------------------------------------------
# Hand-rolled optimisers (Table I: SGD for ISOLET, Nesterov for HAR,
# Adam for the image models).
# ----------------------------------------------------------------------


def make_optimizer(kind, lr):
    """→ (init_state, update) for a params pytree."""
    if kind in ("sgd", "nesterov"):
        mu = 0.9 if kind == "nesterov" else 0.0

        def init(params):
            return jax.tree.map(jnp.zeros_like, params)

        def update(grads, state, params, step):
            new_v = jax.tree.map(lambda v, g: mu * v - lr * g, state, grads)
            if kind == "nesterov":
                new_p = jax.tree.map(
                    lambda p, v, g: p + mu * v - lr * g, params, new_v, grads
                )
            else:
                new_p = jax.tree.map(lambda p, v: p + v, params, new_v)
            return new_p, new_v

        return init, update

    if kind == "adam":
        b1, b2, eps = 0.9, 0.999, 1e-8

        def init(params):
            z = jax.tree.map(jnp.zeros_like, params)
            return (z, jax.tree.map(jnp.zeros_like, params))

        def update(grads, state, params, step):
            m, v = state
            m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
            v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
            t = step + 1
            mhat = jax.tree.map(lambda a: a / (1 - b1**t), m)
            vhat = jax.tree.map(lambda a: a / (1 - b2**t), v)
            new_p = jax.tree.map(
                lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
            )
            return new_p, (m, v)

        return init, update
    raise ValueError(kind)


# Table I hyperparameters (epochs are overridable; defaults scaled to
# the synthetic corpus size).
CONFIGS = {
    "isolet": {"opt": "sgd", "lr": 0.05, "batch": 64},
    "har": {"opt": "nesterov", "lr": 0.02, "batch": 32},
    "mnist": {"opt": "adam", "lr": 1e-3, "batch": 128},
    "svhn": {"opt": "adam", "lr": 1e-3, "batch": 128},
    "cifar10": {"opt": "adam", "lr": 1e-3, "batch": 128},
}


def train_model(model, epochs, train_n, test_n, seed=7, log=print):
    """Train one model; returns (params, test_x, test_y, history)."""
    cfg = CONFIGS[model]
    tx, ty, vx, vy = datasets.generate(model, train_n, test_n, seed)
    rng = np.random.default_rng(seed)
    params = init_params(model, rng)
    init, update = make_optimizer(cfg["opt"], cfg["lr"])
    state = init(params)

    @jax.jit
    def loss_fn(params, x, y):
        logits = forward(model, params, x)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(logp[jnp.arange(x.shape[0]), y])

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    @jax.jit
    def acc_fn(params, x, y):
        return jnp.mean(jnp.argmax(forward(model, params, x), axis=1) == y)

    history = []
    step = 0
    n = len(tx)
    for epoch in range(epochs):
        perm = rng.permutation(n)
        ep_loss = 0.0
        nb = 0
        for s in range(0, n, cfg["batch"]):
            idx = perm[s : s + cfg["batch"]]
            loss, grads = grad_fn(params, tx[idx], ty[idx])
            params, state = update(grads, state, params, step)
            step += 1
            ep_loss += float(loss)
            nb += 1
        acc = float(acc_fn(params, vx, vy))
        history.append({"epoch": epoch, "loss": ep_loss / nb, "test_acc": acc})
        log(f"[{model}] epoch {epoch:3d}  loss {ep_loss / nb:.4f}  test acc {acc:.4f}")
    return params, vx, vy, history


def export(model, params, vx, vy, out_dir):
    os.makedirs(out_dir, exist_ok=True)
    ptw.save(
        os.path.join(out_dir, f"{model}.ptw"),
        {k: np.asarray(v) for k, v in params.items()},
    )
    ptw.save(
        os.path.join(out_dir, f"{model}_test.ptw"),
        {"x": vx.reshape(len(vx), -1), "y": vy.astype(np.float32)},
    )
    print(f"exported {model} weights + {len(vx)}-sample test split → {out_dir}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts/weights")
    ap.add_argument("--models", default="isolet,har")
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--train-n", type=int, default=2600)
    ap.add_argument("--test-n", type=int, default=520)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()

    t0 = time.time()
    for model in args.models.split(","):
        model = model.strip()
        params, vx, vy, hist = train_model(
            model, args.epochs, args.train_n, args.test_n, args.seed
        )
        export(model, params, vx, vy, args.out_dir)
    print(f"training pipeline done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
