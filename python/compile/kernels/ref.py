"""Pure-Python posit/PLAM oracle — the correctness reference for the
Pallas kernel and the positjax codec.

Deliberately written with scalar Python ints and Fraction-free exact
float math, structured like the SoftPosit reference implementation and
*sharing no code* with positjax: agreement between the two is the
correctness signal (plus cross-checks against the Rust substrate's
doctest values).
"""

import math

import numpy as np


def _mask(n: int) -> int:
    return (1 << n) - 1


def decode(bits: int, n: int, es: int):
    """bits → ('zero'|'nar'|'normal', sign, scale, frac, frac_bits)."""
    bits &= _mask(n)
    if bits == 0:
        return ("zero", 0, 0, 0, 0)
    if bits == 1 << (n - 1):
        return ("nar", 0, 0, 0, 0)
    sign = bits >> (n - 1)
    absv = ((-bits) & _mask(n)) if sign else bits
    # Regime run length.
    rbit = (absv >> (n - 2)) & 1
    run = 0
    for i in range(n - 1):
        if (absv >> (n - 2 - i)) & 1 == rbit:
            run += 1
        else:
            break
    k = run - 1 if rbit else -run
    rem = max(n - (1 + run + 1), 0)
    tail = absv & ((1 << rem) - 1)
    e_bits = min(es, rem)
    e = (tail >> (rem - e_bits)) << (es - e_bits) if e_bits else 0
    frac_bits = rem - e_bits
    frac = tail & ((1 << frac_bits) - 1)
    return ("normal", sign, (k << es) + e, frac, frac_bits)


def to_float(bits: int, n: int, es: int) -> float:
    """Exact real value of a posit (NaR → nan)."""
    cls, sign, scale, frac, fb = decode(bits, n, es)
    if cls == "zero":
        return 0.0
    if cls == "nar":
        return math.nan
    v = (1 + frac / (1 << fb)) * 2.0**scale
    return -v if sign else v


def encode(sign: int, scale: int, frac: int, frac_bits: int, sticky: bool, n: int, es: int) -> int:
    """RNE posit encode of (-1)^sign · 2^scale · (1 + frac/2^frac_bits)."""
    avail = n - 1
    k = scale >> es
    e = scale - (k << es)
    if k >= 0 and k + 2 > avail:
        body = _mask(avail)  # maxpos
    elif k < 0 and 1 - k > avail:
        body = 1  # minpos
    else:
        rlen = k + 2 if k >= 0 else 1 - k
        regime = (((1 << (k + 1)) - 1) << 1) if k >= 0 else 1
        total = rlen + es + frac_bits
        big = (regime << (es + frac_bits)) | (e << frac_bits) | frac
        if total > avail:
            shift = total - avail
            kept = big >> shift
            guard = (big >> (shift - 1)) & 1
            below = big & ((1 << (shift - 1)) - 1)
            st = sticky or below != 0
            if guard and (st or (kept & 1)):
                kept += 1
            if kept >> avail:
                kept = _mask(avail)
            body = kept if kept else 1
        else:
            body = big << (avail - total)
    return ((-body) & _mask(n)) if sign else body


def from_float(x: float, n: int, es: int) -> int:
    """Nearest posit to a float (RNE); nan/inf → NaR."""
    if x == 0.0:
        return 0
    if not math.isfinite(x):
        return 1 << (n - 1)
    sign = 1 if x < 0 else 0
    m, exp = math.frexp(abs(x))  # m in [0.5, 1)
    scale = exp - 1
    # 53-bit fraction of (2m - 1) ∈ [0, 1).
    frac = int((2 * m - 1) * (1 << 52))
    return encode(sign, scale, frac, 52, False, n, es)


def plam_mul(a: int, b: int, n: int, es: int) -> int:
    """Bit-level PLAM product (paper Eqs. 14-21) on scalar patterns."""
    ca, sa, ka, fa, fba = decode(a, n, es)
    cb, sb, kb, fb, fbb = decode(b, n, es)
    if ca == "nar" or cb == "nar":
        return 1 << (n - 1)
    if ca == "zero" or cb == "zero":
        return 0
    width = 32
    fa_al = fa << (width - fba)
    fb_al = fb << (width - fbb)
    fsum = fa_al + fb_al
    carry = fsum >> width
    frac = fsum & _mask(width)
    return encode(sa ^ sb, ka + kb + carry, frac, width, False, n, es)


def exact_mul(a: int, b: int, n: int, es: int) -> int:
    """Bit-level exact posit product (paper Eqs. 3-10)."""
    ca, sa, ka, fa, fba = decode(a, n, es)
    cb, sb, kb, fb, fbb = decode(b, n, es)
    if ca == "nar" or cb == "nar":
        return 1 << (n - 1)
    if ca == "zero" or cb == "zero":
        return 0
    siga = (1 << fba) | fa
    sigb = (1 << fbb) | fb
    prod = siga * sigb  # exact integer
    hidden = fba + fbb  # hidden bit position if no overflow
    scale = ka + kb
    if prod >> (hidden + 1):
        scale += 1
        hidden += 1
    frac = prod & ((1 << hidden) - 1)
    return encode(sa ^ sb, scale, frac, hidden, False, n, es)


def quantize(x: np.ndarray, n: int, es: int) -> np.ndarray:
    """Round every element to its nearest posit value."""
    flat = np.asarray(x, dtype=np.float64).ravel()
    out = np.array([to_float(from_float(float(v), n, es), n, es) for v in flat])
    return out.reshape(np.shape(x)).astype(np.float32)


def plam_matmul_ref(a: np.ndarray, b: np.ndarray, n: int = 16, es: int = 1) -> np.ndarray:
    """Reference semantics of the Pallas kernel: quantise inputs to
    posits, take bit-level PLAM products, sum in float32 over K."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    m, k = a.shape
    k2, nn = b.shape
    assert k == k2
    abits = [[from_float(float(a[i, p]), n, es) for p in range(k)] for i in range(m)]
    bbits = [[from_float(float(b[p, j]), n, es) for j in range(nn)] for p in range(k)]
    out = np.zeros((m, nn), dtype=np.float32)
    for i in range(m):
        for j in range(nn):
            acc = np.float32(0)
            for p in range(k):
                prod = to_float(plam_mul(abits[i][p], bbits[p][j], n, es), n, es)
                acc = np.float32(acc + np.float32(prod))
            out[i, j] = acc
    return out
