"""L1 — Pallas PLAM GEMM kernel.

The paper's compute hot-spot: a matrix multiply whose scalar products
are Posit<n,es> PLAM products (log-domain fraction adds, Eqs. 14-21)
instead of exact multiplies. Layout per the TPU adaptation in DESIGN.md
§4: the grid tiles M×N; each program decodes its A-row-block and
B-col-block once (VPU integer work), forms the PLAM products in the log
domain, reconstructs them and reduces over K.

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call
the CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).

Accumulation semantics: each PLAM product is rounded to the output
posit grid (the hardware unit emits a correctly-rounded Posit<n,es>)
and the rounded products are summed in f32 — the Johnson-style [7]
"log product, linear accumulate" design. The Rust engine's quire path
(`plam::nn`) is the stricter EMAC variant; `ref.py` mirrors *this*
kernel's semantics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..positjax import codec
from ..positjax.codec import FRAC_W, SCALE_NAR, SCALE_ZERO


def _plam_products(a_blk, b_blk, n: int, es: int):
    """PLAM products of a_blk [bm,K] × b_blk [K,bn] → values [bm,K,bn].

    Pure elementwise/broadcast integer ops (VPU work on TPU).
    """
    abits = codec.from_f32(a_blk, n, es)
    bbits = codec.from_f32(b_blk, n, es)
    sa, ka, fa = codec.decode(abits, n, es)
    sb, kb, fb = codec.decode(bbits, n, es)

    sa = sa[:, :, None]
    ka = ka[:, :, None]
    fa = fa[:, :, None]
    sb = sb[None, :, :]
    kb = kb[None, :, :]
    fb = fb[None, :, :]

    sign = sa ^ sb  # Eq. 14
    scale = ka + kb  # Eqs. 15-16
    fsum = fa + fb  # Eq. 17
    carry = fsum >> FRAC_W  # Eqs. 20-21
    frac = fsum & ((1 << FRAC_W) - 1)
    scale = scale + carry

    any_zero = jnp.logical_or(ka == SCALE_ZERO, kb == SCALE_ZERO)
    any_nar = jnp.logical_or(ka == SCALE_NAR, kb == SCALE_NAR)

    # Exact product reconstruction by IEEE-754 bit assembly (jnp.exp2 is
    # inexact on f32 and breaks RNE ties); product scales of n ≤ 16
    # posits stay within f32's exponent range (|scale| ≤ 2·max_scale).
    val = codec.compose_f32(sign, jnp.clip(scale, -126, 127), frac)
    val = jnp.where(any_zero, 0.0, val)
    val = jnp.where(any_nar, jnp.nan, val)
    # Round each product to the output posit grid — the hardware PLAM
    # unit emits a correctly-rounded Posit<n,es> (paper §V). The
    # reconstruction above is exact in f32, so this single quantisation
    # step is the only rounding, matching `encode` in the scalar oracle.
    return codec.quantize_f32(val, n, es)


def _exact_products(a_blk, b_blk, n: int, es: int):
    """Exact Posit<n,es> products (Fig. 3 datapath) — the in-kernel
    baseline for the PLAM-vs-exact ablation."""
    from ..positjax import plam as plam_ops

    abits = codec.from_f32(a_blk, n, es)
    bbits = codec.from_f32(b_blk, n, es)
    prod_bits = plam_ops.exact_mul(
        abits[:, :, None], bbits[None, :, :], n, es
    )
    return codec.to_f32(prod_bits, n, es)


def _kernel(a_ref, b_ref, o_ref, *, n, es, mul):
    if mul == "plam":
        prods = _plam_products(a_ref[...], b_ref[...], n, es)
    elif mul == "exact":
        prods = _exact_products(a_ref[...], b_ref[...], n, es)
    else:
        raise ValueError(f"unknown mul {mul!r}")
    o_ref[...] = jnp.sum(prods, axis=1)


@functools.partial(
    jax.jit, static_argnames=("n", "es", "block_m", "block_n", "mul")
)
def plam_matmul(
    a, b, n: int = 16, es: int = 1, block_m: int = 8, block_n: int = 8, mul: str = "plam"
):
    """`a [M,K] ×̃ b [K,N] → [M,N]` with posit scalar products
    (`mul='plam'` approximate, `mul='exact'` baseline).

    M must be divisible by block_m and N by block_n (wrap with
    `plam_matmul_padded` otherwise). K is unblocked: each program holds
    one A-row-block and one B-col-block in VMEM.
    """
    m, k = a.shape
    k2, nn = b.shape
    assert k == k2, f"inner dims {k} != {k2}"
    assert m % block_m == 0 and nn % block_n == 0, "pad M/N to block multiples"

    grid = (m // block_m, nn // block_n)
    return pl.pallas_call(
        functools.partial(_kernel, n=n, es=es, mul=mul),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, nn), jnp.float32),
        interpret=True,
    )(a, b)


def plam_matmul_padded(
    a, b, n: int = 16, es: int = 1, block_m: int = 8, block_n: int = 8, mul: str = "plam"
):
    """plam_matmul for arbitrary M/N: zero-pads to block multiples and
    slices the result back. Zero rows/cols are PLAM-exact (0 ×̃ x = 0) so
    padding never changes the valid region."""
    m, k = a.shape
    _, nn = b.shape
    mp = (m + block_m - 1) // block_m * block_m
    np_ = (nn + block_n - 1) // block_n * block_n
    a_p = jnp.pad(a, ((0, mp - m), (0, 0)))
    b_p = jnp.pad(b, ((0, 0), (0, np_ - nn)))
    out = plam_matmul(a_p, b_p, n=n, es=es, block_m=block_m, block_n=block_n, mul=mul)
    return out[:m, :nn]


def posit_quantize(x, n: int = 16, es: int = 1):
    """Elementwise posit quantisation (RNE round-trip) — used by the L2
    model between layers so activations live on the posit grid."""
    return codec.quantize_f32(x, n, es)
