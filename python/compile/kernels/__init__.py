"""L1 kernels: the Pallas PLAM GEMM and its pure-Python oracle."""
