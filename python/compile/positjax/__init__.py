"""positjax — vectorised posit emulation for JAX (build-time only).

Implements the paper's Posit<n,es> encode/decode and the PLAM
logarithm-approximate multiplier (Eqs. 14-21) as pure jnp integer ops, so
they can live inside Pallas kernels and be AOT-lowered to HLO. Supports
n <= 16 (the DNN experiments use Posit<16,1>, paper Table II).

All functions are elementwise/vectorised over int32 bit-pattern arrays.
"""

from .codec import (
    decode,
    encode,
    from_f32,
    to_f32,
    quantize_f32,
    mask,
    nar,
    maxpos,
    minpos,
    FRAC_W,
)
from .plam import plam_mul, exact_mul

__all__ = [
    "decode",
    "encode",
    "from_f32",
    "to_f32",
    "quantize_f32",
    "plam_mul",
    "exact_mul",
    "mask",
    "nar",
    "maxpos",
    "minpos",
    "FRAC_W",
]
