"""PLAM and exact posit multiplication on bit-pattern arrays (jnp).

`plam_mul` is the vectorised twin of the hardware datapath in the
paper's Fig. 4 (and of `rust/src/posit/plam.rs`): sign XOR, scale add,
fraction *add* in the log domain (Eq. 17), carry into the scale
(Eqs. 19-21), RNE encode. `exact_mul` implements the Fig. 3 exact
datapath and exists as the in-JAX baseline.
"""

import jax.numpy as jnp

from .codec import (
    FRAC_W,
    SCALE_NAR,
    SCALE_ZERO,
    decode,
    encode,
    mask,
    nar,
)


def _specials(sa_scale, sb_scale):
    """Zero/NaR masks for a pair of decoded scales."""
    any_nar = jnp.logical_or(sa_scale == SCALE_NAR, sb_scale == SCALE_NAR)
    any_zero = jnp.logical_or(sa_scale == SCALE_ZERO, sb_scale == SCALE_ZERO)
    return any_nar, any_zero


def plam_mul(a, b, n: int, es: int):
    """Approximate product of two posit bit arrays (paper Eqs. 14-21)."""
    sa, ka, fa = decode(a, n, es)
    sb, kb, fb = decode(b, n, es)
    any_nar, any_zero = _specials(ka, kb)

    sign = sa ^ sb  # Eq. 14
    scale = ka + kb  # Eqs. 15-16 (k‖e fixed-point add)
    fsum = fa + fb  # Eq. 17: F = f_A + f_B
    carry = fsum >> FRAC_W  # Eq. 20/21 condition (F >= 1)
    frac = fsum & mask(FRAC_W)
    scale = scale + carry

    # Specials ride through encode via sentinel scales.
    scale = jnp.where(any_zero, SCALE_ZERO, scale)
    scale = jnp.where(any_nar, SCALE_NAR, scale)
    frac = jnp.where(jnp.logical_or(any_zero, any_nar), 0, frac)
    return encode(sign, scale, frac, jnp.zeros_like(frac, jnp.bool_), n, es)


def exact_mul(a, b, n: int, es: int):
    """Exact product of two posit bit arrays (paper Eqs. 3-10)."""
    sa, ka, fa = decode(a, n, es)
    sb, kb, fb = decode(b, n, es)
    any_nar, any_zero = _specials(ka, kb)

    sign = sa ^ sb
    scale = ka + kb
    # Significands 1.f at Q FRAC_W: product has 2*FRAC_W+2 bits — do it
    # in float64-free integer math via two int32 halves? FRAC_W=13 →
    # sig <= 2^14, product <= 2^28: fits int32 exactly.
    siga = (1 << FRAC_W) | fa
    sigb = (1 << FRAC_W) | fb
    prod = siga * sigb  # [2^26, 2^28)
    overflow = prod >> (2 * FRAC_W + 1)  # F >= 2 (Eqs. 9-10)
    scale = scale + overflow
    hidden = 2 * FRAC_W + overflow
    fr_full = prod & ((1 << hidden) - 1)  # hidden-bit-stripped fraction
    # Fold to FRAC_W bits + sticky (single rounding happens in encode).
    drop = hidden - FRAC_W
    frac = fr_full >> drop
    sticky = (fr_full & ((1 << drop) - 1)) != 0

    scale = jnp.where(any_zero, SCALE_ZERO, scale)
    scale = jnp.where(any_nar, SCALE_NAR, scale)
    frac = jnp.where(jnp.logical_or(any_zero, any_nar), 0, frac)
    return encode(sign, scale, frac, sticky, n, es)


def plam_mul_nar_check(a, b, n: int, es: int):
    """plam_mul + explicit NaR pattern output (already handled inside
    encode; kept for API parity with SoftPosit's isNaR checks)."""
    out = plam_mul(a, b, n, es)
    sa_, ka, _ = decode(a, n, es)
    sb_, kb, _ = decode(b, n, es)
    any_nar, _ = _specials(ka, kb)
    return jnp.where(any_nar, nar(n), out)
