"""Vectorised Posit<n,es> codec in pure jnp integer ops (n <= 16).

Bit patterns live in int32 arrays (low n bits). The decoded form is
(sign, scale, frac) with the fraction left-aligned to FRAC_W bits, the
exact layout the PLAM kernel's log-domain adder wants (paper Eq. 12:
a posit is the fixed-point number k‖e‖f in the log domain).

Everything here must stay jit-/pallas-traceable: no data-dependent
Python control flow, only elementwise lax/jnp ops.
"""

import jax.numpy as jnp
from jax import lax

# Fraction alignment width. 13 bits hold the widest n<=16 fraction
# (12 bits for Posit<16,1>) plus one guard position, and keeps every
# intermediate (body = regime + es + FRAC_W bits) inside int32.
FRAC_W = 13

# Sentinel scales for specials (match rust/src/posit/tables.rs).
SCALE_ZERO = -(2 ** 14)
SCALE_NAR = 2 ** 14


def mask(n: int) -> int:
    """Low-n-bits mask."""
    return (1 << n) - 1


def nar(n: int) -> int:
    """Not-a-Real pattern 100…0."""
    return 1 << (n - 1)


def maxpos(n: int) -> int:
    """Largest positive pattern 011…1."""
    return (1 << (n - 1)) - 1


def minpos(n: int) -> int:
    """Smallest positive pattern 000…1."""
    return 1


def decode(bits, n: int, es: int):
    """bits(int32) → (sign, scale, frac) with frac aligned to FRAC_W.

    sign is 0/1 int32; scale is int32 (2^es·k + e, or a sentinel for
    zero/NaR); frac is int32 in [0, 2^FRAC_W).
    """
    bits = jnp.asarray(bits, jnp.int32) & mask(n)
    is_zero = bits == 0
    is_nar = bits == nar(n)

    sign = (bits >> (n - 1)) & 1
    absv = jnp.where(sign == 1, (-bits) & mask(n), bits)

    # Regime run-length detection over the n-1 bits after the sign:
    # normalise to "count leading ones" by inverting negative regimes.
    rbit = (absv >> (n - 2)) & 1
    body = jnp.where(rbit == 1, absv, (~absv) & mask(n - 1)) & mask(n - 1)
    # run = number of leading ones of body within n-1 bits (static unroll).
    run = jnp.zeros_like(bits)
    alive = jnp.ones_like(bits, dtype=jnp.bool_)
    for i in range(n - 1):
        bit = (body >> (n - 2 - i)) & 1
        alive = jnp.logical_and(alive, bit == 1)
        run = run + alive.astype(jnp.int32)
    k = jnp.where(rbit == 1, run - 1, -run)

    # Remaining bits after sign + regime + terminator.
    used = 1 + run + 1
    rem = jnp.maximum(n - used, 0)
    tail = absv & ((1 << rem) - 1)

    e_bits = jnp.minimum(es, rem)
    e = jnp.where(
        e_bits > 0,
        (tail >> (rem - e_bits)) << (es - e_bits),
        0,
    )
    frac_bits = rem - e_bits
    frac = tail & ((1 << frac_bits) - 1)
    frac_aligned = frac << (FRAC_W - frac_bits)

    scale = (k << es) + e
    scale = jnp.where(is_zero, SCALE_ZERO, scale)
    scale = jnp.where(is_nar, SCALE_NAR, scale)
    frac_aligned = jnp.where(jnp.logical_or(is_zero, is_nar), 0, frac_aligned)
    sign = jnp.where(jnp.logical_or(is_zero, is_nar), 0, sign)
    return sign, scale, frac_aligned


def encode(sign, scale, frac, sticky, n: int, es: int):
    """(sign, scale, frac@FRAC_W, sticky) → posit bits, with RNE.

    Handles the sentinel scales (zero/NaR pass through) and posit
    saturation (never rounds to zero or NaR). All int32.
    """
    sign = jnp.asarray(sign, jnp.int32)
    scale = jnp.asarray(scale, jnp.int32)
    frac = jnp.asarray(frac, jnp.int32)
    sticky = jnp.asarray(sticky, jnp.bool_)

    avail = n - 1
    k = scale >> es  # arithmetic shift = floor division
    e = scale - (k << es)

    # Regime construction. Clamp k to the representable window first so
    # every later shift amount stays in [0, 31].
    k_hi = avail - 2
    k_lo = -(avail - 1)
    sat_hi = k > k_hi
    sat_lo = k < k_lo
    kc = jnp.clip(k, k_lo, k_hi)

    pos = kc >= 0
    rlen = jnp.where(pos, kc + 2, 1 - kc)
    regime = jnp.where(pos, ((1 << (jnp.where(pos, kc, 0) + 1)) - 1) << 1, 1)

    total = rlen + es + FRAC_W
    body = (regime << (es + FRAC_W)) | (e << FRAC_W) | frac

    # total >= avail for every supported format; shift == 0 (no rounding)
    # only for n=16, es=0 with a minimal regime.
    shift = jnp.maximum(total - avail, 0)
    sh1 = jnp.maximum(shift - 1, 0)
    kept = body >> shift
    guard = jnp.where(shift > 0, (body >> sh1) & 1, 0)
    below = body & ((1 << sh1) - 1)
    st = jnp.logical_or(sticky, below != 0)
    round_up = jnp.logical_and(guard == 1, jnp.logical_or(st, (kept & 1) == 1))
    kept = kept + round_up.astype(jnp.int32)

    # Carry past maxpos clamps; zero clamps to minpos.
    kept = jnp.where(kept >> avail != 0, maxpos(n), kept)
    kept = jnp.where(kept == 0, minpos(n), kept)
    kept = jnp.where(sat_hi, maxpos(n), kept)
    kept = jnp.where(sat_lo, minpos(n), kept)

    out = jnp.where(sign == 1, (-kept) & mask(n), kept)
    out = jnp.where(scale == SCALE_ZERO, 0, out)
    out = jnp.where(scale == SCALE_NAR, nar(n), out)
    return out.astype(jnp.int32)


def from_f32(x, n: int, es: int):
    """f32 array → posit bits (RNE). NaN/Inf → NaR, ±0 → 0.

    f32 subnormals (< 2^-126) are far below every n<=16 posit's minpos
    and saturate to ±minpos, so their exact significand is irrelevant.
    """
    x = jnp.asarray(x, jnp.float32)
    bits = lax.bitcast_convert_type(x, jnp.int32)
    sign = (bits >> 31) & 1
    biased = (bits >> 23) & 0xFF
    mant = bits & ((1 << 23) - 1)

    is_zero = jnp.logical_and(biased == 0, mant == 0)
    is_special = biased == 0xFF  # inf/nan
    is_subnormal = jnp.logical_and(biased == 0, mant != 0)

    scale = biased - 127
    # Fold the 23-bit mantissa to FRAC_W bits + sticky (single rounding
    # happens in encode).
    drop = 23 - FRAC_W
    frac = mant >> drop
    sticky = (mant & ((1 << drop) - 1)) != 0

    # Subnormals: treat as minimal normal; encode saturates to minpos.
    scale = jnp.where(is_subnormal, -127, scale)
    frac = jnp.where(is_subnormal, 0, frac)

    scale = jnp.where(is_zero, SCALE_ZERO, scale)
    scale = jnp.where(is_special, SCALE_NAR, scale)
    return encode(sign, scale, frac, sticky, n, es)


def compose_f32(sign, scale, frac):
    """Exact f32 `(-1)^sign · 2^scale · (1 + frac/2^FRAC_W)` built by
    direct IEEE-754 bit assembly. jnp.exp2 is NOT exact on f32 (e.g.
    exp2(13) ≈ 8192.004), which silently breaks RNE ties downstream —
    every value construction in positjax goes through here instead.
    Requires scale ∈ [-126, 127] (true for every n ≤ 16 posit product).
    """
    fbits = ((jnp.asarray(sign, jnp.int32) & 1) << 31) \
        | ((jnp.asarray(scale, jnp.int32) + 127) << 23) \
        | (jnp.asarray(frac, jnp.int32) << (23 - FRAC_W))
    return lax.bitcast_convert_type(fbits, jnp.float32)


def to_f32(bits, n: int, es: int):
    """Posit bits → exact f32 value (NaR → NaN)."""
    sign, scale, frac = decode(bits, n, es)
    safe_scale = jnp.clip(scale, -126, 127)
    val = compose_f32(sign, safe_scale, frac)
    val = jnp.where(scale == SCALE_ZERO, 0.0, val)
    val = jnp.where(scale == SCALE_NAR, jnp.nan, val)
    return val


def quantize_f32(x, n: int, es: int):
    """Round an f32 array to the nearest Posit<n,es> values (f32 out)."""
    return to_f32(from_f32(x, n, es), n, es)
