"""L2 — the paper's compute graphs in JAX, calling the L1 kernel.

`mlp_forward` is the AOT-exported serving graph: a Table I MLP whose
dense layers run through the Pallas PLAM GEMM (`mul='plam'`), the exact
posit GEMM (`mul='exact'`), or plain f32 (`mul='float'`). Activations
are re-quantised to the posit grid between layers, mirroring the Rust
engine and Deep PeNSieve.

The model topologies/parameter names match `rust/src/nn/model.rs` so
PTW weight files round-trip across the boundary (layer{i}.w / layer{i}.b
with i = the Rust `layers` index).
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.plam_matmul import plam_matmul_padded, posit_quantize

# Rust `Model::layers` indices of the Dense layers in each MLP topology.
MLP_TOPOLOGY = {
    "isolet": {"dims": [617, 128, 64, 26], "layer_idx": [0, 2, 4]},
    "har": {"dims": [561, 512, 512, 6], "layer_idx": [0, 2, 4]},
}


def init_mlp_params(name, seed=0):
    """He-uniform init, keyed like the Rust loader expects."""
    spec = MLP_TOPOLOGY[name]
    rng = np.random.default_rng(seed)
    params = {}
    for li, (i, o) in zip(spec["layer_idx"], zip(spec["dims"][:-1], spec["dims"][1:])):
        bound = np.sqrt(6.0 / i)
        params[f"layer{li}.w"] = rng.uniform(-bound, bound, (o, i)).astype(np.float32)
        params[f"layer{li}.b"] = np.zeros((o,), np.float32)
    return params


def mlp_forward(params, x, name="isolet", n=16, es=1, mul="plam"):
    """Batch forward: x [B, in] → logits [B, out].

    Weights are stored Rust-style as [out, in]; the kernel computes
    x · wᵀ. With `mul='float'` this is the plain f32 reference graph.
    """
    spec = MLP_TOPOLOGY[name]
    h = x
    last = spec["layer_idx"][-1]
    for li in spec["layer_idx"]:
        w = jnp.asarray(params[f"layer{li}.w"])  # [out, in]
        b = jnp.asarray(params[f"layer{li}.b"])
        if mul == "float":
            h = h @ w.T + b
        else:
            h = posit_quantize(h, n, es)
            wq = posit_quantize(w.T, n, es)
            h = plam_matmul_padded(h, wq, n=n, es=es, mul=mul)
            h = posit_quantize(h + b, n, es)
        if li != last:
            h = jax.nn.relu(h)
    return h


def mlp_forward_fn(params, name="isolet", n=16, es=1, mul="plam"):
    """Close over baked parameters → a single-input serving function
    (what aot.py lowers: rust feeds x, gets logits)."""
    baked = {k: jnp.asarray(v) for k, v in params.items()}

    def fn(x):
        return (mlp_forward(baked, x, name=name, n=n, es=es, mul=mul),)

    return fn
