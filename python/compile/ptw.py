"""PTW1 binary tensor format — the Python half of the Rust loader
(`rust/src/nn/loader.rs`). Little-endian, see the Rust doc comment for
the layout."""

import struct

import numpy as np

MAGIC = b"PTW1"


def save(path, tensors):
    """Write a dict {name: np.ndarray(float32)} to a .ptw file."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name in sorted(tensors):
            arr = np.ascontiguousarray(tensors[name], dtype=np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.tobytes())


def load(path):
    """Read a .ptw file into {name: np.ndarray(float32)}."""
    out = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            (ndim,) = struct.unpack("<I", f.read(4))
            shape = tuple(struct.unpack("<Q", f.read(8))[0] for _ in range(ndim))
            n = int(np.prod(shape)) if shape else 1
            data = np.frombuffer(f.read(n * 4), dtype="<f4").reshape(shape)
            out[name] = data.astype(np.float32)
    return out
