"""AOT lowering: JAX/Pallas → HLO text artifacts for the Rust runtime.

HLO *text* is the interchange format — jax >= 0.5 serialised protos use
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md). Lowered with
return_tuple=True; the Rust side unpacks with `to_tuple`.

Artifacts (all under artifacts/):
  plam_matmul_8.hlo.txt        8×8×8 PLAM GEMM (runtime smoke + benches)
  plam_matmul_64.hlo.txt       64×64×64 PLAM GEMM (serving-scale bench)
  mlp_isolet_plam_b8.hlo.txt   batch-8 ISOLET MLP, PLAM kernels, baked
                               weights (artifacts/weights/isolet.ptw if
                               present, else deterministic init)
  mlp_isolet_float_b8.hlo.txt  same graph in plain f32 (ablation)

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as L2
from . import ptw
from .kernels.plam_matmul import plam_matmul


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (id-safe interchange).

    print_large_constants=True is load-bearing: the default printer
    elides big constants as `{...}`, which the text *parser* then reads
    back as zeros — baked weights would silently vanish.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def write(path: str, text: str):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {len(text):>9} chars  {path}")


def lower_matmul(size: int):
    spec = jax.ShapeDtypeStruct((size, size), jnp.float32)

    def fn(a, b):
        return (plam_matmul(a, b, block_m=min(8, size), block_n=min(8, size)),)

    return jax.jit(fn).lower(spec, spec)


def lower_mlp(weights_dir: str, mul: str, batch: int = 8):
    wpath = os.path.join(weights_dir, "isolet.ptw")
    if os.path.exists(wpath):
        params = ptw.load(wpath)
        src = wpath
    else:
        params = L2.init_mlp_params("isolet", seed=0)
        src = "deterministic-init(seed=0)"
    print(f"mlp weights: {src}")
    fn = L2.mlp_forward_fn(params, name="isolet", mul=mul)
    spec = jax.ShapeDtypeStruct((batch, 617), jnp.float32)
    return jax.jit(fn).lower(spec)


def export_goldens(out_dir: str, weights_dir: str, skip_mlp: bool):
    """Golden input/output pairs for the Rust integration tests: the
    exact tensors the artifacts must reproduce bit-for-bit."""
    import numpy as np

    from . import ptw

    gdir = os.path.join(out_dir, "golden")
    os.makedirs(gdir, exist_ok=True)
    rng = np.random.default_rng(123)

    a = rng.standard_normal((8, 8)).astype(np.float32)
    b = rng.standard_normal((8, 8)).astype(np.float32)
    out = np.array(plam_matmul(a, b))
    ptw.save(os.path.join(gdir, "matmul8.ptw"), {"a": a, "b": b, "out": out})
    print(f"golden matmul8 → {gdir}")

    if not skip_mlp:
        wpath = os.path.join(weights_dir, "isolet.ptw")
        params = ptw.load(wpath) if os.path.exists(wpath) else L2.init_mlp_params("isolet", seed=0)
        x = rng.standard_normal((8, 617)).astype(np.float32) * 0.5
        fn = L2.mlp_forward_fn(params, mul="plam")
        out = np.array(jax.jit(fn)(x)[0])
        ptw.save(os.path.join(gdir, "mlp_isolet_plam_b8.ptw"), {"x": x, "out": out})
        print(f"golden mlp → {gdir}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--weights-dir", default="../artifacts/weights")
    ap.add_argument(
        "--skip-mlp", action="store_true", help="only the kernel artifacts (fast smoke)"
    )
    args = ap.parse_args()

    write(
        os.path.join(args.out_dir, "plam_matmul_8.hlo.txt"),
        to_hlo_text(lower_matmul(8)),
    )
    write(
        os.path.join(args.out_dir, "plam_matmul_64.hlo.txt"),
        to_hlo_text(lower_matmul(64)),
    )
    if not args.skip_mlp:
        write(
            os.path.join(args.out_dir, "mlp_isolet_plam_b8.hlo.txt"),
            to_hlo_text(lower_mlp(args.weights_dir, "plam")),
        )
        write(
            os.path.join(args.out_dir, "mlp_isolet_float_b8.hlo.txt"),
            to_hlo_text(lower_mlp(args.weights_dir, "float")),
        )
    export_goldens(args.out_dir, args.weights_dir, args.skip_mlp)
    print("aot done")


if __name__ == "__main__":
    main()
