"""Synthetic datasets for the Python training pipeline — the same
substitution as `rust/src/data/synth.rs` (DESIGN.md §5): matched shapes
and class counts for ISOLET / UCI-HAR / MNIST / SVHN / CIFAR-10. The
Rust and Python generators need not be bit-identical: the trained
test-set is exported alongside the weights, so Rust evaluates exactly
what Python trained on."""

import numpy as np

SPECS = {
    # name: (input shape, classes, noise level)
    "isolet": ((617,), 26, 1.7),
    "har": ((561,), 6, 3.2),
    "mnist": ((1, 28, 28), 10, 0.35),
    "svhn": ((3, 32, 32), 10, 1.35),
    "cifar10": ((3, 32, 32), 10, 1.45),
}


def generate(name, train_n, test_n, seed=7):
    """→ (train_x, train_y, test_x, test_y) as float32/int arrays."""
    shape, classes, noise = SPECS[name]
    rng = np.random.default_rng(seed ^ 0xDA7A5E7)
    if len(shape) == 1:
        return _numeric(shape[0], classes, noise, train_n, test_n, rng)
    return _images(shape, classes, noise, train_n, test_n, rng)


def _numeric(dim, classes, noise, train_n, test_n, rng):
    informative = dim // 3
    protos = np.zeros((classes, dim), np.float32)
    protos[:, :informative] = rng.standard_normal((classes, informative))
    mixers = (rng.random((8, dim), np.float32) - 0.5) * 0.6

    def split(n):
        ys = np.arange(n) % classes
        xs = protos[ys].copy()
        z = rng.standard_normal((n, 8)).astype(np.float32)
        xs += z @ mixers
        xs += noise * rng.standard_normal((n, dim)).astype(np.float32)
        return xs.astype(np.float32), ys.astype(np.int32)

    tx, ty = split(train_n)
    vx, vy = split(test_n)
    return tx, ty, vx, vy


def _images(shape, classes, noise, train_n, test_n, rng):
    ch, hw, _ = shape

    def render(cls):
        cx = hw / 2 + rng.standard_normal() * 1.5
        cy = hw / 2 + rng.standard_normal() * 1.5
        scale = hw * (0.28 + 0.06 * np.clip(rng.standard_normal(), -1.5, 1.5))
        angle = (cls % 5) * np.pi / 5 + rng.standard_normal() * 0.08
        family = cls // 5
        sa, ca = np.sin(angle), np.cos(angle)
        hue = np.array(
            [
                0.65 + 0.35 * np.sin(cls * 0.7 + c * 2.1) + rng.standard_normal() * 0.05
                for c in range(ch)
            ]
        )
        yy, xx = np.mgrid[0:hw, 0:hw].astype(np.float64)
        dx, dy = xx - cx, yy - cy
        u = ca * dx + sa * dy
        v = -sa * dx + ca * dy
        r = np.sqrt(dx * dx + dy * dy)
        if family == 0:
            bar = np.exp(-((v / (scale * 0.18)) ** 2))
            tick = np.exp(-((u / (scale * 0.15)) ** 2) - ((v - scale * 0.4) / (scale * 0.3)) ** 2)
            inten = np.minimum(bar + 0.7 * tick, 1.0)
        else:
            ring = np.exp(-(((r - scale * 0.8) / (scale * 0.2)) ** 2))
            grating = 0.5 + 0.5 * np.sin(u / scale * 6.0)
            inten = np.minimum(0.8 * ring + 0.4 * grating * np.exp(-((r / scale / 1.4) ** 2)), 1.0)
        img = np.stack(
            [
                np.clip(inten * hue[c] + noise * 0.5 * rng.standard_normal((hw, hw)), 0, 1)
                for c in range(ch)
            ]
        )
        return img.astype(np.float32)

    def split(n):
        ys = (np.arange(n) % classes).astype(np.int32)
        xs = np.stack([render(int(c)) for c in ys])
        return xs, ys

    tx, ty = split(train_n)
    vx, vy = split(test_n)
    return tx, ty, vx, vy
